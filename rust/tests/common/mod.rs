//! The shared differential-test harness.
//!
//! Every differential suite in `rust/tests/` used to hand-roll the same
//! scaffolding: the builder × exec-space engine grid, deterministic
//! scene/cloud generators, oracle plumbing, and result-sorting helpers.
//! They live here once now — `predicate_differential`,
//! `first_hit_differential`, `service_and_distributed`, `wire_fuzz`, and
//! `nearest_geometry_differential` all `mod common;` this file.
//!
//! Each integration test compiles as its own crate, so any one suite
//! only uses a subset of these helpers; the `dead_code` allow keeps the
//! unused remainder warning-free per crate.
#![allow(dead_code)]

use arbor::baselines::brute::BruteForce;
use arbor::bvh::nearest::Neighbor;
use arbor::bvh::{Bvh, QueryOutput, QueryPredicate, TraversalMode};
use arbor::coordinator::distributed::Partition;
use arbor::data::rng::Rng;
use arbor::data::shapes::{PointCloud, Shape};
use arbor::data::workloads::{collapse_boxes, drift_boxes, jitter_boxes, teleport_boxes};
use arbor::exec::ExecSpace;
use arbor::geometry::predicates::{FirstHit, Spatial};
use arbor::geometry::{Aabb, Point, Ray, Sphere};

/// The two workload shapes every differential suite sweeps: balanced
/// (filled) and imbalanced (hollow) per-query work.
pub const SHAPES: [Shape; 2] = [Shape::FilledCube, Shape::HollowCube];

/// Both distributed partitions, for the distributed differential grids.
pub const PARTITIONS: [Partition; 2] = [Partition::Block, Partition::MortonBlock];

/// The builder × exec-space × traversal-mode engine grid: every suite
/// checks Karras and Apetrei construction under serial and threaded
/// execution, and each built tree is exercised through all three
/// traversal modes — the binary reference walk, the 4-wide SIMD walk
/// over quantized child boxes, and the forced scalar fallback of that
/// wide walk. The label names the combination for assertion messages.
pub fn engines(boxes: &[Aabb]) -> Vec<(String, Bvh, ExecSpace)> {
    let mut out = Vec::new();
    for (space_name, space) in [("serial", ExecSpace::serial()), ("mt", ExecSpace::with_threads(4))]
    {
        for (builder_name, built) in [
            ("karras", Bvh::build(&space, boxes)),
            ("apetrei", Bvh::build_apetrei(&space, boxes)),
        ] {
            for (mode_name, mode) in [
                ("binary", TraversalMode::Binary),
                ("wide", TraversalMode::WideSimd),
                ("wide-scalar", TraversalMode::WideScalar),
            ] {
                let mut engine = built.clone();
                engine.set_traversal_mode(mode);
                out.push((
                    format!("{builder_name}/{space_name}/{mode_name}"),
                    engine,
                    space.clone(),
                ));
            }
        }
    }
    out
}

/// Adversarial scenes for the wide tree's quantized child boxes: every
/// degenerate axis, coordinate magnitude, and mixed-extent layout that
/// stresses the u8 grid's round-trip (zero extents → zero scale, huge
/// spreads → coarse grids, tiny clusters next to far outliers → child
/// boxes much smaller than one grid step). Differential suites run
/// these through the full engine grid against brute force.
pub fn edge_case_boxes() -> Vec<(&'static str, Vec<Aabb>)> {
    let mut rng = Rng::new(0xED6E);
    let mut scenes: Vec<(&'static str, Vec<Aabb>)> = Vec::new();

    // Every box the identical zero-extent point: all quantization scales
    // collapse to zero and every child is the whole parent.
    scenes.push((
        "coincident",
        (0..64).map(|_| Aabb::from_point(Point::new(1.5, -2.0, 3.25))).collect(),
    ));

    // Colinear points: two axes have exactly zero extent at every level.
    scenes.push((
        "colinear-x",
        (0..200)
            .map(|i| Aabb::from_point(Point::new(i as f32 * 0.37, 4.0, -1.0)))
            .collect(),
    ));

    // Coplanar thin slabs: one degenerate axis, finite extents elsewhere.
    scenes.push((
        "coplanar-z",
        (0..150)
            .map(|_| {
                let c = random_point(&mut rng, 50.0);
                let hx = rng.uniform(0.1, 2.0);
                let hy = rng.uniform(0.1, 2.0);
                Aabb::new(
                    Point::new(c[0] - hx, c[1] - hy, 7.0),
                    Point::new(c[0] + hx, c[1] + hy, 7.0),
                )
            })
            .collect(),
    ));

    // A tight cluster plus far outliers: the root grid step dwarfs the
    // cluster boxes, so their quantized images round to single cells.
    let mut spread: Vec<Aabb> = (0..180)
        .map(|_| {
            let c = random_point(&mut rng, 0.01);
            Aabb::new(c - Point::splat(1e-4), c + Point::splat(1e-4))
        })
        .collect();
    spread.push(Aabb::from_point(Point::new(1.0e6, -1.0e6, 5.0e5)));
    spread.push(Aabb::from_point(Point::new(-7.5e5, 2.0e5, -9.0e5)));
    scenes.push(("huge-spread", spread));

    // Sub-grid-step extents everywhere: boxes far smaller than one 1/255
    // slice of any parent, so min/max quantize to adjacent (or equal)
    // cells and conservative snapping is the whole story.
    scenes.push((
        "tiny-extent",
        (0..160)
            .map(|_| {
                let c = random_point(&mut rng, 30.0);
                Aabb::new(c - Point::splat(1e-6), c + Point::splat(1e-6))
            })
            .collect(),
    ));

    // Mixed degenerate and finite boxes, including duplicates.
    let mut mixed = Vec::new();
    for i in 0..120 {
        let c = random_point(&mut rng, 10.0);
        match i % 3 {
            0 => mixed.push(Aabb::from_point(c)),
            1 => mixed.push(Aabb::new(c - Point::splat(0.8), c + Point::splat(0.8))),
            _ => {
                mixed.push(Aabb::from_point(Point::new(0.0, 0.0, 0.0)));
            }
        }
    }
    scenes.push(("mixed-degenerate", mixed));

    scenes
}

/// A deterministic cloud plus its boxes and brute-force oracle — the
/// standard scene of the differential suites.
pub fn scene(shape: Shape, n: usize, seed: u64) -> (PointCloud, Vec<Aabb>, BruteForce) {
    let cloud = PointCloud::generate(shape, n, seed);
    let boxes = cloud.boxes();
    let brute = BruteForce::new(&boxes);
    (cloud, boxes, brute)
}

/// Finite-extent boxes around the cloud points: random (non-axis) rays
/// and geometry queries genuinely overlap these, unlike the measure-zero
/// point boxes.
pub fn inflate(cloud: &PointCloud, half: f32) -> Vec<Aabb> {
    cloud
        .points
        .iter()
        .map(|p| Aabb::new(*p - Point::splat(half), *p + Point::splat(half)))
        .collect()
}

/// The motion-magnitude sweep for the dynamic-scene (refit) suites:
/// per-box displacements spanning the whole refit spectrum, from
/// topology-preserving (small jitter, rigid drift) through degrading
/// (large jitter, collapse) to topology-shredding (teleport). `extent`
/// should be the scene's characteristic half-width so magnitudes scale
/// with the workload.
pub fn moved_scenes(boxes: &[Aabb], extent: f32, seed: u64) -> Vec<(&'static str, Vec<Aabb>)> {
    vec![
        ("jitter-small", jitter_boxes(boxes, 0.02 * extent, seed)),
        ("jitter-large", jitter_boxes(boxes, 0.5 * extent, seed ^ 0xA5A5)),
        ("drift", drift_boxes(boxes, Point::new(0.8 * extent, -0.3 * extent, 0.1 * extent))),
        ("teleport", teleport_boxes(boxes, 7, Point::splat(25.0 * extent))),
        ("collapse", collapse_boxes(boxes, Point::splat(0.25 * extent), 1.0)),
    ]
}

/// A uniform point in `[-scale, scale]^3`.
pub fn random_point(rng: &mut Rng, scale: f32) -> Point {
    Point::new(
        rng.uniform(-scale, scale),
        rng.uniform(-scale, scale),
        rng.uniform(-scale, scale),
    )
}

/// Sorts a result row for unordered (spatial) comparisons.
pub fn sorted(mut v: Vec<u32>) -> Vec<u32> {
    v.sort();
    v
}

/// Zips parallel index/squared-distance rows back into `Neighbor`s, for
/// full index-level equality against a k-NN oracle.
pub fn neighbors_from(indices: &[u32], distances: &[f32]) -> Vec<Neighbor> {
    indices
        .iter()
        .zip(distances)
        .map(|(&index, &distance_squared)| Neighbor { distance_squared, index })
        .collect()
}

/// [`neighbors_from`] for query `qi`'s CSR row of a batched output.
pub fn neighbors_for(out: &QueryOutput, qi: usize) -> Vec<Neighbor> {
    neighbors_from(out.results_for(qi), out.distances_for(qi))
}

/// Random rays and segments plus axis-parallel rays aimed exactly at
/// existing (zero-extent) points, so both hit-rich and grazing cases are
/// always present.
pub fn ray_set(cloud: &PointCloud, seed: u64) -> Vec<FirstHit> {
    let mut rng = Rng::new(seed);
    let mut rays = Vec::new();
    for _ in 0..40 {
        let origin = random_point(&mut rng, 2.0 * cloud.a);
        let dir = Point::new(
            rng.uniform(-1.0, 1.0),
            rng.uniform(-1.0, 1.0),
            rng.uniform(-1.0, 1.0),
        );
        if dir.norm() < 1e-3 {
            continue;
        }
        if rays.len() % 2 == 0 {
            rays.push(FirstHit(Ray::new(origin, dir)));
        } else {
            rays.push(FirstHit(Ray::segment(origin, dir, rng.uniform(0.5, 4.0))));
        }
    }
    // Axis rays straight through existing points: the direction has exact
    // zero components, so the slab test is exact along the other axes and
    // the targeted zero-extent leaf box is a guaranteed hit.
    for i in (0..cloud.points.len()).step_by(101) {
        let p = cloud.points[i];
        rays.push(FirstHit(Ray::new(
            Point::new(p[0], p[1], p[2] - 2.0 * cloud.a),
            Point::new(0.0, 0.0, 1.0),
        )));
    }
    rays
}

/// One random well-formed predicate of any wire kind, for round-trip and
/// service fuzzing. `scale` bounds the coordinates; every kind tag is
/// reachable.
pub fn random_predicate(rng: &mut Rng, scale: f32) -> QueryPredicate {
    let center = random_point(rng, scale);
    match rng.below(10) {
        0 => QueryPredicate::intersects_sphere(center, rng.uniform(0.0, scale)),
        1 => QueryPredicate::intersects_box(random_box(rng, center, scale)),
        2 => QueryPredicate::intersects_ray(random_ray(rng, center)),
        3 => QueryPredicate::attach(
            Spatial::IntersectsSphere(Sphere::new(center, rng.uniform(0.0, scale))),
            rng.next_u64(),
        ),
        4 => QueryPredicate::attach(
            Spatial::IntersectsBox(random_box(rng, center, scale)),
            rng.next_u64(),
        ),
        5 => QueryPredicate::attach(
            Spatial::IntersectsRay(random_ray(rng, center)),
            rng.next_u64(),
        ),
        6 => QueryPredicate::nearest(center, 1 + rng.below(32)),
        7 => QueryPredicate::nearest_sphere(
            Sphere::new(center, rng.uniform(0.0, scale)),
            1 + rng.below(32),
        ),
        8 => QueryPredicate::nearest_box(random_box(rng, center, scale), 1 + rng.below(32)),
        _ => QueryPredicate::first_hit(random_ray(rng, center)),
    }
}

/// A deterministic wire batch cycling through **all 10 kinds**, one
/// predicate per anchor point: sphere / box / ray, the three attach
/// variants, nearest point / sphere / box, first-hit. The first-hit
/// rays are axis-parallel shots from below the anchor, so they hit
/// real extents on inflated scenes.
pub fn wire_batch(points: &[Point], radius: f32, k: usize) -> Vec<QueryPredicate> {
    let half = Point::splat(radius);
    points
        .iter()
        .enumerate()
        .map(|(i, p)| match i % 10 {
            0 => QueryPredicate::intersects_sphere(*p, radius),
            1 => QueryPredicate::intersects_box(Aabb::new(*p - half, *p + half)),
            2 => QueryPredicate::intersects_ray(Ray::new(*p, Point::new(0.3, 1.0, -0.2))),
            3 => QueryPredicate::attach(
                Spatial::IntersectsSphere(Sphere::new(*p, radius)),
                i as u64,
            ),
            4 => QueryPredicate::attach(
                Spatial::IntersectsBox(Aabb::new(*p - half, *p + half)),
                i as u64,
            ),
            5 => QueryPredicate::attach(
                Spatial::IntersectsRay(Ray::new(*p, Point::new(-1.0, 0.4, 0.1))),
                i as u64,
            ),
            6 => QueryPredicate::nearest(*p, k),
            7 => QueryPredicate::nearest_sphere(Sphere::new(*p, radius), k),
            8 => QueryPredicate::nearest_box(Aabb::new(*p - half, *p + half), k),
            _ => QueryPredicate::first_hit(Ray::new(
                Point::new(p[0], p[1], p[2] - 5.0),
                Point::new(0.0, 0.0, 1.0),
            )),
        })
        .collect()
}

/// Brute-force oracle for one wire predicate of any kind: (indices,
/// distances) with the same conventions as the tree entry points
/// (ascending indices for spatial kinds; (distance, index)-sorted with
/// squared distances for nearest; the entry parameter for first-hit).
pub fn brute_one(brute: &BruteForce, pred: &QueryPredicate) -> (Vec<u32>, Vec<f32>) {
    fn split(neighbors: Vec<Neighbor>) -> (Vec<u32>, Vec<f32>) {
        (
            neighbors.iter().map(|n| n.index).collect(),
            neighbors.iter().map(|n| n.distance_squared).collect(),
        )
    }
    match pred {
        QueryPredicate::Spatial(s) | QueryPredicate::Attach(s, _) => {
            (brute.spatial(s), Vec::new())
        }
        QueryPredicate::Nearest(n) => split(brute.nearest_to(&n.geometry, n.k)),
        QueryPredicate::NearestSphere(n) => split(brute.nearest_to(&n.geometry, n.k)),
        QueryPredicate::NearestBox(n) => split(brute.nearest_to(&n.geometry, n.k)),
        QueryPredicate::FirstHit(r) => match brute.first_hit(r) {
            Some(h) => (vec![h.index], vec![h.t]),
            None => (Vec::new(), Vec::new()),
        },
    }
}

/// A random well-formed (possibly zero-extent) box around `center`.
fn random_box(rng: &mut Rng, center: Point, scale: f32) -> Aabb {
    let half = Point::new(
        rng.uniform(0.0, scale),
        rng.uniform(0.0, scale),
        rng.uniform(0.0, scale),
    );
    Aabb::new(center - half, center + half)
}

/// A random ray from `origin`: unbounded or a finite segment, never
/// zero-direction.
fn random_ray(rng: &mut Rng, origin: Point) -> Ray {
    let mut dir = Point::new(
        rng.uniform(-1.0, 1.0),
        rng.uniform(-1.0, 1.0),
        rng.uniform(-1.0, 1.0),
    );
    if dir.norm() < 1e-3 {
        dir = Point::new(1.0, 0.0, 0.0);
    }
    if rng.below(2) == 0 {
        Ray::new(origin, dir)
    } else {
        Ray::segment(origin, dir, rng.uniform(0.1, 10.0))
    }
}
