//! Acceptance suite for the streaming batched distributed engine:
//! `DistributedTree::query_batch` must be bit-for-bit the per-query
//! `query_predicate` walk AND the brute-force oracle — indices,
//! distances, tie-breaks — across all 10 wire kinds × Block/MortonBlock
//! × serial/threaded execution, with the spatial path streaming every
//! match through the callback engine (no per-rank result vectors) and
//! rank sub-batches spreading across pool workers.

mod common;

use std::sync::Arc;

use arbor::bvh::QueryPredicate;
use arbor::coordinator::distributed::{DistributedTree, Partition};
use arbor::coordinator::service::{SearchService, ServiceConfig, SubmitError};
use arbor::data::shapes::Shape;
use arbor::exec::ExecSpace;
use arbor::geometry::Point;

use common::{brute_one, inflate, scene, wire_batch, PARTITIONS, SHAPES};

#[test]
fn query_batch_matches_per_query_and_brute_on_every_kind() {
    for shape in SHAPES {
        let (cloud, _point_boxes, _) = scene(shape, 1500, 71);
        // Finite extents so rays and geometry queries genuinely overlap.
        let boxes = inflate(&cloud, 0.25);
        let brute = arbor::baselines::brute::BruteForce::new(&boxes);
        let preds = wire_batch(&cloud.points[..200], 0.9, 6);
        for partition in PARTITIONS {
            for (space_name, space) in
                [("serial", ExecSpace::serial()), ("mt", ExecSpace::with_threads(4))]
            {
                let dt = DistributedTree::build(&space, &boxes, 7, partition);
                assert_eq!(dt.n_ranks(), 7);
                let (out, stats) = dt.query_batch(&space, &preds);
                assert_eq!(out.offsets.len(), preds.len() + 1);
                assert_eq!(out.total(), out.indices.len());
                let mut spatial_total = 0usize;
                for (qi, pred) in preds.iter().enumerate() {
                    let label = format!("{shape:?}/{partition:?}/{space_name} query {qi}");
                    // Per-query distributed walk: exact equality.
                    let (want_idx, want_dist, _) = dt.query_predicate(pred);
                    assert_eq!(out.results_for(qi), &want_idx[..], "{label}");
                    // Brute oracle: exact equality (indices AND
                    // distances, so tie-breaks are part of the contract).
                    let (brute_idx, brute_dist) = brute_one(&brute, pred);
                    assert_eq!(out.results_for(qi), &brute_idx[..], "{label} vs oracle");
                    match pred {
                        QueryPredicate::Spatial(_) | QueryPredicate::Attach(..) => {
                            spatial_total += want_idx.len();
                        }
                        _ => {
                            assert_eq!(out.distances_for(qi), &want_dist[..], "{label} dist");
                            assert_eq!(
                                out.distances_for(qi),
                                &brute_dist[..],
                                "{label} dist vs oracle"
                            );
                        }
                    }
                }
                // Acceptance: spatial matches streamed via callback into
                // the per-query accumulators — the streamed counter is
                // incremented only inside the callback, so equality here
                // means no result took a per-rank detour.
                assert_eq!(
                    stats.streamed_results, spatial_total,
                    "{shape:?}/{partition:?}/{space_name}"
                );
                assert_eq!(stats.results, out.total());
                assert!(stats.ranks_contacted <= 7);
                assert!(stats.forwarded_queries >= stats.ranks_contacted);
            }
        }
    }
}

#[test]
fn threaded_engine_spreads_rank_sub_batches() {
    // Rank-level parallelism on the ExecSpace: the per-query distributed
    // path never touches a thread, the batched engine must. Dynamic
    // chunk claiming makes a single-worker run theoretically possible,
    // so retry a few heavy rounds before judging.
    let space = ExecSpace::with_threads(4);
    let (cloud, _point_boxes, _) = scene(Shape::FilledCube, 20_000, 5);
    let boxes = inflate(&cloud, 0.3);
    let dt = DistributedTree::build(&space, &boxes, 12, Partition::MortonBlock);
    let preds: Vec<QueryPredicate> = cloud.points[..2000]
        .iter()
        .map(|p| QueryPredicate::intersects_sphere(*p, 2.5))
        .collect();
    let mut workers = 0usize;
    for _ in 0..5 {
        let (_, stats) = dt.query_batch(&space, &preds);
        workers = workers.max(stats.worker_threads);
        if workers >= 2 {
            break;
        }
    }
    assert!(workers >= 2, "rank sub-batches never left the calling thread");
    // And the threaded execution is bit-for-bit the serial one.
    let serial = ExecSpace::serial();
    let (a, sa) = dt.query_batch(&serial, &preds);
    let (b, _) = dt.query_batch(&space, &preds);
    assert_eq!(sa.worker_threads, 1, "serial space executes on the caller only");
    assert_eq!(a.offsets, b.offsets);
    assert_eq!(a.indices, b.indices);
    assert_eq!(a.distances, b.distances);
}

#[test]
fn rank_count_honors_the_request() {
    // Regression: ceiling-division chunking used to create fewer ranks
    // than requested (3 shards for n = 6, n_ranks = 4) while n_ranks()
    // reported the shard count as if nothing were wrong — callers sizing
    // work per rank were lied to. The acceptance shape:
    let space = ExecSpace::serial();
    let (cloud, boxes, brute) = scene(Shape::FilledCube, 6, 17);
    for partition in PARTITIONS {
        let dt = DistributedTree::build(&space, &boxes, 4, partition);
        assert_eq!(dt.n_ranks(), 4, "{partition:?}");
        let mut sizes: Vec<usize> = (0..4).map(|r| dt.rank_len(r)).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 1, 2, 2], "{partition:?} remainder distribution");
        // The rebalanced layout still answers correctly.
        for (qi, pred) in wire_batch(&cloud.points, 1.0, 3).iter().enumerate() {
            let (idx, _, _) = dt.query_predicate(pred);
            let (want, _) = brute_one(&brute, pred);
            assert_eq!(idx, want, "{partition:?} query {qi}");
        }
    }
}

#[test]
fn service_over_shutdown_returns_errors_not_panics() {
    // Regression for the service satellite, exercised over the
    // *distributed* backend: submit-after-stop and query-after-stop are
    // Results, and handles accepted before the stop drain to Ok.
    let space = ExecSpace::serial();
    let (cloud, boxes, _) = scene(Shape::FilledCube, 800, 23);
    let dt = Arc::new(DistributedTree::build(&space, &boxes, 4, Partition::MortonBlock));
    let svc = SearchService::start_distributed(Arc::clone(&dt), ServiceConfig::default());
    let pendings: Vec<_> = wire_batch(&cloud.points[..40], 0.8, 4)
        .iter()
        .map(|p| svc.submit(*p).expect("service running"))
        .collect();
    svc.shutdown();
    for (qi, p) in pendings.into_iter().enumerate() {
        p.wait().unwrap_or_else(|e| panic!("accepted query {qi} must drain, got {e:?}"));
    }
    assert_eq!(
        svc.submit(QueryPredicate::nearest(Point::origin(), 1)).err(),
        Some(SubmitError::Stopped),
        "submit after shutdown is an error, not a panic"
    );
}
