//! Differential tests for the first-hit ray-cast subsystem.
//!
//! A brute-force ray-march oracle (`BruteForce::first_hit`, sharing the
//! traversal's tie-break) is compared against every entry point the
//! query family owns: the direct traversal, the batched fixed-width
//! engine (sorted and unsorted), the CSR facade, the service wire path
//! (byte-encoded `TAG_FIRST_HIT` submissions), and the distributed
//! forward/merge — over Karras and Apetrei builds on serial and threaded
//! execution spaces, plus the degenerate geometry the slab test must
//! survive. The prune-versus-scan test at the bottom is the performance
//! acceptance: ordered descent must examine strictly fewer internal
//! nodes than the all-hits traversal it replaces.

mod common;

use std::sync::Arc;

use arbor::baselines::brute::BruteForce;
use arbor::bvh::first_hit::{first_hit, first_hit_monitored};
use arbor::bvh::traversal::for_each_spatial_monitored;
use arbor::bvh::{Bvh, QueryOptions, QueryPredicate, RayHit};
use arbor::coordinator::distributed::{DistributedTree, Partition};
use arbor::coordinator::service::{SearchService, ServiceConfig};
use arbor::coordinator::wire;
use arbor::data::rng::Rng;
use arbor::data::shapes::{PointCloud, Shape};
use arbor::exec::ExecSpace;
use arbor::geometry::predicates::{FirstHit, IntersectsRay};
use arbor::geometry::{Aabb, Point, Ray};

use common::{edge_case_boxes, engines, inflate, ray_set, SHAPES};

#[test]
fn first_hit_matches_brute_force_everywhere() {
    for (si, shape) in SHAPES.iter().enumerate() {
        let cloud = PointCloud::generate(*shape, 2000, 400 + si as u64);
        // Two leaf geometries: zero-extent point boxes (axis rays hit
        // them exactly) and inflated boxes (random rays hit them often).
        for (variant, boxes) in [("points", cloud.boxes()), ("solid", inflate(&cloud, 0.6))] {
            check_every_engine(*shape, variant, &cloud, &boxes, 31 + si as u64);
        }
    }
}

/// Runs the ray set against every engine combination on one leaf
/// geometry, comparing direct, batched, and facade answers to the
/// brute-force ray-march oracle.
fn check_every_engine(shape: Shape, variant: &str, cloud: &PointCloud, boxes: &[Aabb], seed: u64) {
    let brute = BruteForce::new(boxes);
    let rays = ray_set(cloud, seed);
    let want: Vec<Option<RayHit>> = rays.iter().map(|r| brute.first_hit(&r.0)).collect();
    assert!(
        want.iter().any(|h| h.is_some()),
        "{shape:?}/{variant}: no ray hits anything — test workload is vacuous"
    );

    for (name, bvh, space) in engines(boxes) {
        // Direct traversal.
        let mut stack = Vec::new();
        for (qi, r) in rays.iter().enumerate() {
            assert_eq!(
                first_hit(&bvh, r, &mut stack),
                want[qi],
                "{shape:?}/{variant}/{name} direct ray {qi}"
            );
        }
        // Batched fixed-width engine, sorted and unsorted.
        for sort in [false, true] {
            let got = bvh.query_first_hit(&space, &rays, sort);
            assert_eq!(got, want, "{shape:?}/{variant}/{name} batched sort={sort}");
        }
        // CSR facade (2P and tight 1P): one row per query, the entry
        // parameter in `distances`.
        let facade: Vec<QueryPredicate> =
            rays.iter().map(|r| QueryPredicate::first_hit(r.0)).collect();
        for (opt_name, opts) in [
            ("2p", QueryOptions { buffer_size: None, sort_queries: true }),
            ("1p-tight", QueryOptions { buffer_size: Some(1), sort_queries: false }),
        ] {
            let out = bvh.query(&space, &facade, &opts);
            assert_eq!(out.overflow_queries, 0, "first-hit cannot overflow");
            for (qi, w) in want.iter().enumerate() {
                match w {
                    Some(h) => {
                        assert_eq!(
                            out.results_for(qi),
                            &[h.index],
                            "{shape:?}/{variant}/{name}/{opt_name} ray {qi}"
                        );
                        assert_eq!(out.distances_for(qi), &[h.t]);
                    }
                    None => assert!(
                        out.results_for(qi).is_empty(),
                        "{shape:?}/{variant}/{name}/{opt_name} ray {qi} must miss"
                    ),
                }
            }
        }
    }
}

#[test]
fn first_hit_matches_brute_force_through_wire_and_distributed() {
    let cloud = PointCloud::generate(Shape::FilledCube, 3000, 9);
    let boxes = inflate(&cloud, 0.6); // random rays hit real extents
    let brute = BruteForce::new(&boxes);
    let rays = ray_set(&cloud, 77);
    let want: Vec<Option<RayHit>> = rays.iter().map(|r| brute.first_hit(&r.0)).collect();

    // Service wire path: every ray byte-encoded with TAG_FIRST_HIT and
    // submitted through the batcher.
    let space = ExecSpace::with_threads(2);
    let bvh = Arc::new(Bvh::build(&space, &boxes));
    let svc = SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig { max_batch: 32, threads: 2, ..Default::default() },
    );
    let pendings: Vec<_> = rays
        .iter()
        .map(|r| {
            let mut bytes = Vec::new();
            wire::encode(&QueryPredicate::first_hit(r.0), &mut bytes);
            svc.submit_encoded(&bytes).expect("well-formed first-hit encoding")
        })
        .collect();
    for (qi, pending) in pendings.into_iter().enumerate() {
        let result = pending.wait().expect("service answered");
        match &want[qi] {
            Some(h) => {
                assert_eq!(result.indices, vec![h.index], "wire ray {qi}");
                assert_eq!(result.distances, vec![h.t], "wire ray {qi}");
            }
            None => assert!(result.indices.is_empty(), "wire ray {qi} must miss"),
        }
    }
    assert_eq!(svc.metrics().first_hit_casts(), rays.len() as u64);
    let hits = want.iter().filter(|h| h.is_some()).count() as u64;
    assert_eq!(svc.metrics().first_hit_hits(), hits);

    // Distributed forward/merge under both partitions.
    for partition in [Partition::Block, Partition::MortonBlock] {
        let dt = DistributedTree::build(&space, &boxes, 5, partition);
        for (qi, r) in rays.iter().enumerate() {
            let (got, stats) = dt.first_hit(&r.0);
            assert_eq!(got, want[qi], "{partition:?} ray {qi}");
            assert!(stats.ranks_contacted <= 5);
        }
    }
}

#[test]
fn degenerate_first_hit_cases() {
    let space = ExecSpace::serial();
    // Zero-extent leaf boxes on a line.
    let boxes: Vec<Aabb> = (0..50)
        .map(|i| Aabb::from_point(Point::new(i as f32, 0.0, 0.0)))
        .collect();
    let brute = BruteForce::new(&boxes);
    let bvh = Bvh::build(&space, &boxes);
    let mut stack = Vec::new();

    // Axis-parallel ray through a zero-extent box, approaching along z.
    let through = FirstHit(Ray::new(Point::new(7.0, 0.0, -5.0), Point::new(0.0, 0.0, 1.0)));
    let want = Some(RayHit { index: 7, t: 5.0 });
    assert_eq!(first_hit(&bvh, &through, &mut stack), want);
    assert_eq!(brute.first_hit(&through.0), want);

    // Origin exactly on a point: the hit is at t = 0.
    let on_point = FirstHit(Ray::new(Point::new(7.0, 0.0, 0.0), Point::new(0.0, 0.0, 1.0)));
    assert_eq!(first_hit(&bvh, &on_point, &mut stack), Some(RayHit { index: 7, t: 0.0 }));

    // t_max exactly at the hit is inclusive; any shorter misses.
    let origin = Point::new(-3.0, 0.0, 0.0);
    let dir = Point::new(1.0, 0.0, 0.0);
    let exact = FirstHit(Ray::segment(origin, dir, 3.0));
    assert_eq!(first_hit(&bvh, &exact, &mut stack), Some(RayHit { index: 0, t: 3.0 }));
    assert_eq!(brute.first_hit(&exact.0), Some(RayHit { index: 0, t: 3.0 }));
    let short = FirstHit(Ray::segment(origin, dir, 2.999));
    assert_eq!(first_hit(&bvh, &short, &mut stack), None);
    assert_eq!(brute.first_hit(&short.0), None);

    // Origin inside an extended leaf box.
    let fat = vec![
        Aabb::new(Point::splat(-2.0), Point::splat(2.0)),
        Aabb::from_point(Point::new(10.0, 0.0, 0.0)),
    ];
    let fat_bvh = Bvh::build(&space, &fat);
    let inside = FirstHit(Ray::new(Point::origin(), Point::new(1.0, 0.0, 0.0)));
    assert_eq!(first_hit(&fat_bvh, &inside, &mut stack), Some(RayHit { index: 0, t: 0.0 }));
    assert_eq!(BruteForce::new(&fat).first_hit(&inside.0), Some(RayHit { index: 0, t: 0.0 }));

    // All-miss scene: empty everywhere, through every entry point.
    let miss = FirstHit(Ray::new(Point::new(0.0, 3.0, 0.0), Point::new(1.0, 0.0, 0.0)));
    assert_eq!(first_hit(&bvh, &miss, &mut stack), None);
    assert_eq!(brute.first_hit(&miss.0), None);
    assert_eq!(bvh.query_first_hit(&space, &[miss], true), vec![None]);
    let out = bvh.query(&space, &[QueryPredicate::first_hit(miss.0)], &QueryOptions::default());
    assert_eq!(out.total(), 0);
}

#[test]
fn first_hit_survives_quantization_edge_case_scenes() {
    // Ordered descent over the wide tree's adversarial scenes: entry
    // parameters against quantized (inflated) child boxes may only get
    // smaller than the exact ones, so the (t, index) winner must be
    // unchanged — including on degenerate axes and huge spreads.
    for (scene_name, boxes) in edge_case_boxes() {
        let brute = BruteForce::new(&boxes);
        let mut world = Aabb::empty();
        for b in &boxes {
            world.expand(b);
        }
        let span = (world.max - world.min).norm().max(1.0);
        let mut rng = Rng::new(0xFACE);
        let mut rays = Vec::new();
        for i in 0..25 {
            let target = boxes[(i * 13) % boxes.len()].centroid();
            // Axis-parallel shot exactly at a leaf: the direction's zero
            // components make the slab test exact, so even zero-extent
            // targets are guaranteed hits.
            rays.push(FirstHit(Ray::new(
                Point::new(target[0], target[1], target[2] - 0.5 * span),
                Point::new(0.0, 0.0, 1.0),
            )));
            // Oblique ray from a random offset toward the same leaf.
            let origin = target
                + Point::new(
                    rng.uniform(0.1, 0.4) * span,
                    rng.uniform(-0.3, 0.3) * span,
                    rng.uniform(-0.3, 0.3) * span,
                );
            let dir = target - origin;
            if dir.norm() > 1e-6 {
                rays.push(FirstHit(Ray::new(origin, dir)));
            }
        }
        let want: Vec<Option<RayHit>> = rays.iter().map(|r| brute.first_hit(&r.0)).collect();
        assert!(
            want.iter().any(|h| h.is_some()),
            "{scene_name}: no ray hits anything — test workload is vacuous"
        );
        for (name, bvh, space) in engines(&boxes) {
            for sort in [false, true] {
                let got = bvh.query_first_hit(&space, &rays, sort);
                assert_eq!(got, want, "{scene_name}/{name} sort={sort}");
            }
        }
    }
}

#[test]
fn first_hit_visits_strictly_fewer_internal_nodes_than_all_hits() {
    // The performance acceptance for the ordered descent: on a 10k-leaf
    // scene, casting to the nearest hit must examine strictly fewer
    // internal nodes than the all-hits traversal whose results would be
    // min-reduced — while returning exactly the same answer.
    let cloud = PointCloud::generate(Shape::FilledCube, 10_000, 5);
    let boxes = inflate(&cloud, 0.5); // finite extents: rays really hit
    let bvh = Bvh::build(&ExecSpace::serial(), &boxes);
    let mut rng = Rng::new(13);
    let mut stack = Vec::new();
    let mut fh_stack = Vec::new();
    let (mut total_fh, mut total_all) = (0usize, 0usize);
    let mut hitting_rays = 0usize;
    for _ in 0..25 {
        // From outside the cloud toward a random interior point, so the
        // ray pierces the scene and the bound tightens early.
        let target = cloud.points[rng.below(cloud.points.len())];
        let origin = Point::new(
            3.0 * cloud.a,
            rng.uniform(-cloud.a, cloud.a),
            rng.uniform(-cloud.a, cloud.a),
        );
        let dir = target - origin;
        if dir.norm() < 1e-3 {
            continue;
        }
        let ray = Ray::new(origin, dir);

        let mut fh_nodes = 0usize;
        let hit = first_hit_monitored(&bvh, &FirstHit(ray), &mut fh_stack, |_| fh_nodes += 1);

        // All-hits + min: the recipe first-hit replaces.
        let mut all_nodes = 0usize;
        let mut best_t = f32::INFINITY;
        let mut best_idx = u32::MAX;
        for_each_spatial_monitored(
            &bvh,
            &IntersectsRay(ray),
            &mut stack,
            |obj| {
                if let Some(t) = ray.box_entry(&boxes[obj as usize]) {
                    if t < best_t || (t == best_t && obj < best_idx) {
                        best_t = t;
                        best_idx = obj;
                    }
                }
            },
            |_| all_nodes += 1,
        );

        // Same answer, fewer nodes.
        match hit {
            Some(h) => {
                hitting_rays += 1;
                assert_eq!(h.index, best_idx);
                assert_eq!(h.t, best_t);
                assert!(
                    fh_nodes < all_nodes,
                    "ordered descent must prune: {fh_nodes} vs {all_nodes}"
                );
            }
            None => assert_eq!(best_idx, u32::MAX),
        }
        total_fh += fh_nodes;
        total_all += all_nodes;
    }
    assert!(hitting_rays >= 10, "workload too sparse to be meaningful");
    assert!(
        total_fh < total_all,
        "aggregate node accesses: first-hit {total_fh} vs all-hits {total_all}"
    );
}
