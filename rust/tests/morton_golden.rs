//! Golden-vector tests for the Morton codes: the rust implementation must
//! produce exactly these values, which are the same vectors asserted in
//! `python/tests/test_morton_kernel.py` — keeping the two layers honest
//! without a cross-language build dependency (the live cross-check runs
//! in `runtime_roundtrip.rs`).

use arbor::geometry::{morton, Point};

/// Reference interleave used to derive the goldens.
fn interleave(x: u32, y: u32, z: u32) -> u32 {
    let mut code = 0u32;
    for b in 0..10 {
        code |= ((x >> b) & 1) << (3 * b + 2);
        code |= ((y >> b) & 1) << (3 * b + 1);
        code |= ((z >> b) & 1) << (3 * b);
    }
    code
}

#[test]
fn unit_cube_golden_vectors() {
    let cases: [(Point, u32); 6] = [
        (Point::new(0.0, 0.0, 0.0), 0),
        (Point::new(1.0, 1.0, 1.0), interleave(1023, 1023, 1023)),
        (Point::new(0.5, 0.25, 0.75), interleave(512, 256, 768)),
        (Point::new(0.999, 0.001, 0.5), interleave(1022, 1, 512)),
        // Out-of-range values clamp.
        (Point::new(-0.5, 2.0, 0.5), interleave(0, 1023, 512)),
        (Point::new(0.0009765625, 0.0, 0.0), interleave(1, 0, 0)), // exactly 1/1024
    ];
    for (p, want) in cases {
        assert_eq!(morton::morton32_unit(&p), want, "{p:?}");
    }
}

#[test]
fn axis_order_is_x_highest() {
    // x contributes the most significant interleaved bit: a point with
    // only x set must exceed one with only y set, etc.
    let x = morton::morton32_unit(&Point::new(1.0, 0.0, 0.0));
    let y = morton::morton32_unit(&Point::new(0.0, 1.0, 0.0));
    let z = morton::morton32_unit(&Point::new(0.0, 0.0, 1.0));
    assert!(x > y && y > z);
    assert_eq!(x, morton::expand_bits_10(1023) << 2);
    assert_eq!(y, morton::expand_bits_10(1023) << 1);
    assert_eq!(z, morton::expand_bits_10(1023));
}

#[test]
fn morton64_matches_morton32_on_coarse_grid() {
    // On a 1024-aligned grid the 63-bit code's top 30 bits must order
    // identically to the 30-bit code.
    let pts: Vec<Point> = (0..64)
        .map(|i| {
            let t = i as f32 / 64.0;
            Point::new(t, 1.0 - t, (2.0 * t) % 1.0)
        })
        .collect();
    let mut order32: Vec<usize> = (0..pts.len()).collect();
    let mut order64 = order32.clone();
    order32.sort_by_key(|&i| morton::morton32_unit(&pts[i]));
    order64.sort_by_key(|&i| morton::morton64_unit(&pts[i]));
    // 64-bit refines 32-bit: equal-32-bit groups may permute, others not.
    let codes32: Vec<u32> = order64.iter().map(|&i| morton::morton32_unit(&pts[i])).collect();
    assert!(codes32.windows(2).all(|w| w[0] <= w[1]), "64-bit order respects 32-bit order");
}
