//! Differential tests for the nearest-to-geometry (k-NN) subsystem.
//!
//! The brute-force oracle (`BruteForce::nearest_to`, scoring every box
//! with the exact squared `DistanceTo` leaf metric and the shared
//! (distance, index) tie-break) is compared against every entry point
//! the query family owns — the stack and priority-queue traversals, the
//! Morton-ordered batched engine (`Bvh::query_nearest`, sorted and
//! unsorted), the CSR facade (2P and tight 1P), the service wire path
//! (byte-encoded `TAG_NEAREST`/`TAG_NEAREST_SPHERE`/`TAG_NEAREST_BOX`
//! submissions), and the distributed bound-ordered rank walk — for
//! point, sphere, and box query geometries over the shared harness's
//! Karras + Apetrei × serial + threaded engine grid. Every comparison is
//! full `Neighbor` (index-level) equality, so distance-tie determinism
//! is part of the contract; coincident-center and query-contains-leaf
//! degenerate cases are pinned explicitly.

mod common;

use std::sync::Arc;

use arbor::baselines::brute::BruteForce;
use arbor::bvh::nearest::{nearest_pq, nearest_stack, NearestScratch, Neighbor};
use arbor::bvh::{Bvh, QueryOptions, QueryPredicate};
use arbor::coordinator::distributed::{DistributedTree, Partition};
use arbor::coordinator::service::{SearchService, ServiceConfig};
use arbor::coordinator::wire;
use arbor::data::rng::Rng;
use arbor::data::shapes::{PointCloud, Shape};
use arbor::exec::ExecSpace;
use arbor::geometry::predicates::Nearest;
use arbor::geometry::{Aabb, Point, Sphere};

use common::{
    edge_case_boxes, engines, inflate, neighbors_for, neighbors_from, random_point, scene, SHAPES,
};

/// The k values every suite sweeps: singleton, mid, and a k that often
/// exceeds the number of zero-distance ties.
const KS: [usize; 3] = [1, 5, 12];

/// Deterministic query geometries for one cloud: random points, spheres
/// (zero radius included), and boxes (degenerate point boxes included),
/// plus coincident-center cases aimed exactly at existing data sites.
fn query_sets(
    cloud: &PointCloud,
    seed: u64,
) -> (Vec<Point>, Vec<Sphere>, Vec<Aabb>) {
    let mut rng = Rng::new(seed);
    let mut points = Vec::new();
    let mut spheres = Vec::new();
    let mut boxes = Vec::new();
    for i in 0..25 {
        let c = random_point(&mut rng, 1.2 * cloud.a);
        points.push(c);
        // Every fifth sphere is zero-radius (degenerates to the point
        // metric); radii large enough to swallow leaves are included.
        let r = if i % 5 == 0 { 0.0 } else { rng.uniform(0.1, 0.4 * cloud.a) };
        spheres.push(Sphere::new(c, r));
        // Every fifth box is a degenerate point box.
        if i % 5 == 0 {
            boxes.push(Aabb::from_point(c));
        } else {
            let half = Point::new(
                rng.uniform(0.1, 0.3 * cloud.a),
                rng.uniform(0.1, 0.3 * cloud.a),
                rng.uniform(0.1, 0.3 * cloud.a),
            );
            boxes.push(Aabb::new(c - half, c + half));
        }
    }
    // Coincident centers: queries sitting exactly on data sites, so the
    // nearest distance is exactly 0 and (with duplicated sites) ties are
    // unavoidable.
    for i in (0..cloud.points.len()).step_by(97) {
        let p = cloud.points[i];
        points.push(p);
        spheres.push(Sphere::new(p, 0.5));
        boxes.push(Aabb::new(p - Point::splat(0.25), p + Point::splat(0.25)));
    }
    (points, spheres, boxes)
}

/// Checks stack, pq, and the batched engine against the oracle for one
/// typed query set, with full Neighbor equality.
fn check_typed<G>(
    label: &str,
    bvh: &Bvh,
    space: &ExecSpace,
    brute: &BruteForce,
    geometries: &[G],
    k: usize,
) where
    G: arbor::geometry::predicates::DistanceTo + Copy + Sync,
{
    let queries: Vec<Nearest<G>> = geometries.iter().map(|g| Nearest::new(*g, k)).collect();
    let want: Vec<Vec<Neighbor>> =
        geometries.iter().map(|g| brute.nearest_to(g, k)).collect();
    let mut scratch = NearestScratch::new(k);
    let (mut out_stack, mut out_pq) = (Vec::new(), Vec::new());
    for (qi, q) in queries.iter().enumerate() {
        nearest_stack(bvh, q, &mut scratch, &mut out_stack);
        assert_eq!(out_stack, want[qi], "{label} stack query {qi} k={k}");
        nearest_pq(bvh, q, &mut out_pq);
        assert_eq!(out_pq, want[qi], "{label} pq query {qi} k={k}");
    }
    for sort in [false, true] {
        let out = bvh.query_nearest(space, &queries, sort);
        for (qi, w) in want.iter().enumerate() {
            let got = neighbors_for(&out, qi);
            assert_eq!(&got, w, "{label} batched sort={sort} query {qi} k={k}");
        }
    }
}

#[test]
fn nearest_geometry_matches_brute_force_everywhere() {
    for (si, shape) in SHAPES.iter().enumerate() {
        let (cloud, _, _) = scene(*shape, 1500, 500 + si as u64);
        // Two leaf geometries: zero-extent point boxes and inflated boxes
        // (queries genuinely overlap the latter, exercising the
        // zero-distance tie paths).
        for (variant, boxes) in [("points", cloud.boxes()), ("solid", inflate(&cloud, 0.6))] {
            let brute = BruteForce::new(&boxes);
            let (points, spheres, regions) = query_sets(&cloud, 41 + si as u64);
            for (name, bvh, space) in engines(&boxes) {
                for k in KS {
                    let label = format!("{shape:?}/{variant}/{name}");
                    check_typed(&format!("{label}/point"), &bvh, &space, &brute, &points, k);
                    check_typed(&format!("{label}/sphere"), &bvh, &space, &brute, &spheres, k);
                    check_typed(&format!("{label}/box"), &bvh, &space, &brute, &regions, k);
                }
            }
        }
    }
}

#[test]
fn facade_agrees_with_oracle_under_both_strategies() {
    let (cloud, _, _) = scene(Shape::FilledCube, 2000, 11);
    let boxes = inflate(&cloud, 0.5);
    let brute = BruteForce::new(&boxes);
    let space = ExecSpace::with_threads(4);
    let bvh = Bvh::build(&space, &boxes);
    let (points, spheres, regions) = query_sets(&cloud, 23);
    let k = 7;
    // One mixed facade batch interleaving all three geometries.
    let mut preds = Vec::new();
    let mut want: Vec<Vec<Neighbor>> = Vec::new();
    for ((p, s), b) in points.iter().zip(&spheres).zip(&regions) {
        preds.push(QueryPredicate::nearest(*p, k));
        want.push(brute.nearest_to(p, k));
        preds.push(QueryPredicate::nearest_sphere(*s, k));
        want.push(brute.nearest_to(s, k));
        preds.push(QueryPredicate::nearest_box(*b, k));
        want.push(brute.nearest_to(b, k));
    }
    for (opt_name, opts) in [
        ("2p", QueryOptions { buffer_size: None, sort_queries: true }),
        ("1p-tight", QueryOptions { buffer_size: Some(2), sort_queries: false }),
        ("1p-roomy", QueryOptions { buffer_size: Some(16), sort_queries: true }),
    ] {
        let out = bvh.query(&space, &preds, &opts);
        for (qi, w) in want.iter().enumerate() {
            assert_eq!(&neighbors_for(&out, qi), w, "{opt_name} query {qi}");
        }
    }
}

#[test]
fn wire_service_and_distributed_agree_with_oracle() {
    let (cloud, _, _) = scene(Shape::FilledCube, 2500, 19);
    let boxes = inflate(&cloud, 0.6);
    let brute = BruteForce::new(&boxes);
    let space = ExecSpace::with_threads(2);
    let bvh = Arc::new(Bvh::build(&space, &boxes));
    let (points, spheres, regions) = query_sets(&cloud, 67);
    let k = 6;
    let mut preds = Vec::new();
    let mut want: Vec<Vec<Neighbor>> = Vec::new();
    for ((p, s), b) in points.iter().zip(&spheres).zip(&regions) {
        preds.push(QueryPredicate::nearest(*p, k));
        want.push(brute.nearest_to(p, k));
        preds.push(QueryPredicate::nearest_sphere(*s, k));
        want.push(brute.nearest_to(s, k));
        preds.push(QueryPredicate::nearest_box(*b, k));
        want.push(brute.nearest_to(b, k));
    }

    // Service wire path: every query byte-encoded and submitted through
    // the batcher (small max_batch forces kind sub-splits).
    let svc = SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig { max_batch: 32, threads: 2, ..Default::default() },
    );
    let pendings: Vec<_> = preds
        .iter()
        .map(|p| {
            let mut bytes = Vec::new();
            wire::encode(p, &mut bytes);
            svc.submit_encoded(&bytes).expect("well-formed nearest encoding")
        })
        .collect();
    for (qi, pending) in pendings.into_iter().enumerate() {
        let r = pending.wait().expect("service answered");
        assert_eq!(neighbors_from(&r.indices, &r.distances), want[qi], "wire query {qi}");
    }

    // Distributed bound-ordered rank walk, both partitions, both the
    // typed and the wire entry points.
    for partition in [Partition::Block, Partition::MortonBlock] {
        let dt = DistributedTree::build(&space, &boxes, 5, partition);
        for ((p, s), b) in points.iter().zip(&spheres).zip(&regions) {
            let (got, stats) = dt.nearest_to(p, k);
            assert_eq!(got, brute.nearest_to(p, k), "{partition:?} point");
            assert!(stats.ranks_contacted >= 1 && stats.ranks_contacted <= 5);
            let (got, _) = dt.nearest_to(s, k);
            assert_eq!(got, brute.nearest_to(s, k), "{partition:?} sphere");
            let (got, _) = dt.nearest_to(b, k);
            assert_eq!(got, brute.nearest_to(b, k), "{partition:?} box");
        }
        for (qi, pred) in preds.iter().enumerate() {
            let (idx, dist, _) = dt.query_predicate(pred);
            assert_eq!(neighbors_from(&idx, &dist), want[qi], "{partition:?} wire query {qi}");
        }
    }
}

#[test]
fn nearest_survives_quantization_edge_case_scenes() {
    // k-NN over the wide tree's adversarial scenes: lower-bound pruning
    // must stay conservative when child boxes round to single grid cells
    // (tiny extents), whole degenerate axes (colinear/coplanar), or very
    // coarse grids (huge spreads). Full Neighbor equality against the
    // oracle, including the zero-distance ties from coincident anchors.
    for (scene_name, boxes) in edge_case_boxes() {
        let brute = BruteForce::new(&boxes);
        let mut world = Aabb::empty();
        for b in &boxes {
            world.expand(b);
        }
        let span = (world.max - world.min).norm().max(1.0);
        let mut rng = Rng::new(0xBEEF);
        let (mut points, mut spheres, mut regions) = (Vec::new(), Vec::new(), Vec::new());
        for i in 0..20 {
            let anchor = boxes[(i * 11) % boxes.len()].centroid();
            // Exact coincidence (zero-distance ties) and nearby offsets.
            points.push(anchor);
            points.push(anchor + Point::splat(rng.uniform(0.0, 0.1) * span));
            let r = if i % 4 == 0 { 0.0 } else { rng.uniform(0.0, 0.05) * span };
            spheres.push(Sphere::new(anchor, r));
            let half = Point::splat(rng.uniform(0.0, 0.04) * span);
            regions.push(Aabb::new(anchor - half, anchor + half));
        }
        for (name, bvh, space) in engines(&boxes) {
            for k in [1, 4] {
                let label = format!("{scene_name}/{name}");
                check_typed(&format!("{label}/point"), &bvh, &space, &brute, &points, k);
                check_typed(&format!("{label}/sphere"), &bvh, &space, &brute, &spheres, k);
                check_typed(&format!("{label}/box"), &bvh, &space, &brute, &regions, k);
            }
        }
    }
}

#[test]
fn coincident_and_containment_ties_are_deterministic() {
    // Duplicated sites + queries that contain whole leaf clusters: every
    // entry point must break the resulting exact distance ties toward the
    // smaller original index, matching the oracle bit-for-bit.
    let mut cloud_points: Vec<Point> = (0..60)
        .map(|i| Point::new((i % 10) as f32, ((i / 10) % 3) as f32, 0.0))
        .collect();
    let dups = cloud_points.clone();
    cloud_points.extend(dups); // every site appears as i and i + 60
    let boxes: Vec<Aabb> = cloud_points.iter().map(|p| Aabb::from_point(*p)).collect();
    let brute = BruteForce::new(&boxes);

    // A sphere centered exactly on a duplicated site, containing several
    // leaves; a box containing the whole y = 0 grid row (10 sites × 4
    // copies = 40 zero-distance leaves).
    let on_site = Sphere::new(Point::new(4.0, 1.0, 0.0), 1.0);
    let row = Aabb::new(Point::new(-0.5, -0.25, -0.25), Point::new(9.5, 0.25, 0.25));
    let queries = [
        QueryPredicate::nearest(Point::new(4.0, 1.0, 0.0), 4),
        QueryPredicate::nearest_sphere(on_site, 5),
        QueryPredicate::nearest_box(row, 7),
        // k larger than the tie set: order must stay deterministic past
        // the zero-distance block.
        QueryPredicate::nearest_box(row, 25),
    ];
    for (name, bvh, espace) in engines(&boxes) {
        let out = bvh.query(&espace, &queries, &QueryOptions::default());
        for (qi, pred) in queries.iter().enumerate() {
            let want = match pred {
                QueryPredicate::Nearest(n) => brute.nearest_to(&n.geometry, n.k),
                QueryPredicate::NearestSphere(n) => brute.nearest_to(&n.geometry, n.k),
                QueryPredicate::NearestBox(n) => brute.nearest_to(&n.geometry, n.k),
                _ => unreachable!(),
            };
            assert_eq!(neighbors_for(&out, qi), want, "{name} query {qi}");
        }
    }
    // Pin the exact zero block: the 7 smallest indices among the 40
    // zero-distance leaves of the y = 0 row are simply 0..=6.
    let nn = brute.nearest_to(&row, 7);
    assert!(nn.iter().all(|n| n.distance_squared == 0.0));
    let idx: Vec<u32> = nn.iter().map(|n| n.index).collect();
    assert_eq!(idx, vec![0, 1, 2, 3, 4, 5, 6]);
}

#[test]
fn k_edge_cases_across_geometries() {
    let (_, boxes, brute) = scene(Shape::FilledCube, 40, 3);
    let space = ExecSpace::serial();
    let bvh = Bvh::build(&space, &boxes);
    let q = Sphere::new(Point::origin(), 2.0);
    // k = 0 yields nothing; k > n yields all n, sorted.
    let out = bvh.query_nearest(&space, &[Nearest::new(q, 0)], true);
    assert_eq!(out.total(), 0);
    let out = bvh.query_nearest(&space, &[Nearest::new(q, 100)], true);
    assert_eq!(out.results_for(0).len(), 40);
    let want = brute.nearest_to(&q, 100);
    assert_eq!(out.results_for(0).len(), want.len());
    let d = out.distances_for(0);
    assert!(d.windows(2).all(|w| w[0] <= w[1]), "sorted by distance");
    // Empty tree: no results for any geometry.
    let empty = Bvh::build(&space, &[]);
    let out = empty.query_nearest(&space, &[Nearest::new(q, 5)], true);
    assert_eq!(out.total(), 0);
}
