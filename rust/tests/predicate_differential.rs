//! Differential property tests for the trait-based query layer.
//!
//! Randomized filled/hollow workloads are run through every engine
//! combination — the shared harness's builder × exec-space grid
//! (`common::engines`), CSR (2P and tight-buffer 1P) and callback
//! execution — and compared against the `BruteForce` oracle for every
//! predicate kind: sphere, box, ray (unbounded and segment), and
//! `WithData` attachments. This is the acceptance harness of the trait
//! refactor: the generic engines, the enum facade, and the callback path
//! must all report the same match sets.

mod common;

use std::sync::Mutex;

use arbor::baselines::brute::BruteForce;
use arbor::bvh::{Bvh, QueryOptions, QueryPredicate};
use arbor::data::rng::Rng;
use arbor::data::shapes::Shape;
use arbor::exec::ExecSpace;
use arbor::geometry::predicates::{
    attach, IntersectsBox, IntersectsRay, IntersectsSphere, SpatialPredicate, WithData,
};
use arbor::geometry::{Aabb, Point, Ray, Sphere};

use common::{edge_case_boxes, engines, random_point, scene, SHAPES};

/// Checks one predicate batch on one engine against brute force, for 2P,
/// tight 1P, and callback execution.
fn check_batch<P: SpatialPredicate + Sync>(
    label: &str,
    bvh: &Bvh,
    space: &ExecSpace,
    brute: &BruteForce,
    preds: &[P],
) {
    let want: Vec<Vec<u32>> = preds.iter().map(|p| brute.spatial(p)).collect();

    for (opt_name, opts) in [
        ("2p", QueryOptions { buffer_size: None, sort_queries: true }),
        ("1p-tight", QueryOptions { buffer_size: Some(2), sort_queries: false }),
    ] {
        let out = bvh.query_spatial(space, preds, &opts);
        for (qi, expect) in want.iter().enumerate() {
            let mut got = out.results_for(qi).to_vec();
            got.sort();
            assert_eq!(&got, expect, "{label} {opt_name} query {qi}");
        }
    }

    // Callback path: collect (query, object) pairs concurrently.
    let matches: Mutex<Vec<(u32, u32)>> = Mutex::new(Vec::new());
    bvh.query_with_callback(space, preds, |q, obj| {
        matches.lock().unwrap().push((q, obj));
    });
    let mut got = matches.into_inner().unwrap();
    got.sort();
    let mut expect_pairs = Vec::new();
    for (qi, expect) in want.iter().enumerate() {
        for &obj in expect {
            expect_pairs.push((qi as u32, obj));
        }
    }
    expect_pairs.sort();
    assert_eq!(got, expect_pairs, "{label} callback");
}

#[test]
fn sphere_and_box_predicates_match_brute_force_everywhere() {
    for (si, shape) in SHAPES.iter().enumerate() {
        let (cloud, boxes, brute) = scene(*shape, 2000, 100 + si as u64);
        let mut rng = Rng::new(7 + si as u64);

        let spheres: Vec<IntersectsSphere> = (0..40)
            .map(|_| {
                let c = random_point(&mut rng, cloud.a);
                IntersectsSphere(Sphere::new(c, rng.uniform(0.5, 4.0)))
            })
            .collect();
        let regions: Vec<IntersectsBox> = (0..40)
            .map(|_| {
                let c = random_point(&mut rng, cloud.a);
                let half = Point::new(
                    rng.uniform(0.2, 3.0),
                    rng.uniform(0.2, 3.0),
                    rng.uniform(0.2, 3.0),
                );
                IntersectsBox(Aabb::new(c - half, c + half))
            })
            .collect();

        for (name, bvh, space) in engines(&boxes) {
            check_batch(&format!("{shape:?}/{name}/sphere"), &bvh, &space, &brute, &spheres);
            check_batch(&format!("{shape:?}/{name}/box"), &bvh, &space, &brute, &regions);
        }
    }
}

#[test]
fn ray_predicates_match_brute_force_everywhere() {
    for (si, shape) in SHAPES.iter().enumerate() {
        let (cloud, boxes, brute) = scene(*shape, 1500, 300 + si as u64);
        let mut rng = Rng::new(17 + si as u64);

        let mut rays: Vec<IntersectsRay> = Vec::new();
        // Random rays and segments (consistency: hit sets must agree even
        // when grazing) ...
        for _ in 0..30 {
            let origin = random_point(&mut rng, cloud.a);
            let dir = Point::new(
                rng.uniform(-1.0, 1.0),
                rng.uniform(-1.0, 1.0),
                rng.uniform(-1.0, 1.0),
            );
            if dir.norm() < 1e-3 {
                continue;
            }
            if rays.len() % 2 == 0 {
                rays.push(IntersectsRay(Ray::new(origin, dir)));
            } else {
                rays.push(IntersectsRay(Ray::segment(origin, dir, rng.uniform(0.5, 3.0))));
            }
        }
        // ... plus axis-aligned rays straight through existing points
        // (guaranteed hits: the direction has exact zero components, so
        // the slab test is exact along the other axes).
        for i in (0..cloud.points.len()).step_by(97) {
            let p = cloud.points[i];
            rays.push(IntersectsRay(Ray::new(
                Point::new(p[0], p[1], p[2] - 2.0 * cloud.a),
                Point::new(0.0, 0.0, 1.0),
            )));
        }
        // At least one axis ray must actually hit its target point.
        assert!(
            rays.iter().any(|r| !brute.spatial(r).is_empty()),
            "{shape:?}: no ray hits anything — test workload is vacuous"
        );

        for (name, bvh, space) in engines(&boxes) {
            check_batch(&format!("{shape:?}/{name}/ray"), &bvh, &space, &brute, &rays);
        }
    }
}

#[test]
fn quantized_child_boxes_survive_degenerate_scenes() {
    // Adversarial scenes for the wide tree's u8-quantized child boxes:
    // degenerate (zero-extent) axes, huge coordinate spreads, and
    // sub-grid-step extents. Every engine in the grid — including both
    // wide traversal modes — must still match brute force exactly,
    // because quantization is only ever allowed to inflate.
    for (scene_name, boxes) in edge_case_boxes() {
        let brute = BruteForce::new(&boxes);
        let mut world = Aabb::empty();
        for b in &boxes {
            world.expand(b);
        }
        let span = (world.max - world.min).norm().max(1.0);
        let mut rng = Rng::new(0xC0FFEE);
        let mut spheres = Vec::new();
        let mut regions = Vec::new();
        for i in 0..30 {
            // Anchor queries on actual leaf boxes (zero-radius spheres at
            // leaf centroids are guaranteed hits), so even the outlier
            // scenes are non-vacuous.
            let anchor = boxes[(i * 7) % boxes.len()].centroid();
            spheres.push(IntersectsSphere(Sphere::new(anchor, rng.uniform(0.0, 0.05) * span)));
            let half = Point::splat(rng.uniform(0.0, 0.03) * span);
            regions.push(IntersectsBox(Aabb::new(anchor - half, anchor + half)));
        }
        assert!(
            spheres.iter().any(|s| !brute.spatial(s).is_empty()),
            "{scene_name}: no sphere hits anything — test workload is vacuous"
        );
        for (name, bvh, space) in engines(&boxes) {
            check_batch(&format!("{scene_name}/{name}/sphere"), &bvh, &space, &brute, &spheres);
            check_batch(&format!("{scene_name}/{name}/box"), &bvh, &space, &brute, &regions);
        }
    }
}

#[test]
fn attachment_predicates_are_transparent_and_carry_data() {
    let (cloud, boxes, brute) = scene(Shape::FilledSphere, 1200, 5);
    let mut rng = Rng::new(23);

    let tagged: Vec<WithData<IntersectsSphere, u64>> = (0..50)
        .map(|i| {
            let c = random_point(&mut rng, cloud.a);
            attach(IntersectsSphere(Sphere::new(c, rng.uniform(0.5, 3.0))), i * i)
        })
        .collect();
    for (qi, p) in tagged.iter().enumerate() {
        assert_eq!(p.data, (qi * qi) as u64);
    }
    for (name, bvh, space) in engines(&boxes) {
        check_batch(&format!("attach/{name}"), &bvh, &space, &brute, &tagged);
        // The attachment changes nothing about the match set.
        let plain: Vec<IntersectsSphere> = tagged.iter().map(|t| t.pred).collect();
        let a = bvh.query_spatial(&space, &tagged, &QueryOptions::default());
        let b = bvh.query_spatial(&space, &plain, &QueryOptions::default());
        assert_eq!(a.offsets, b.offsets, "{name}");
        assert_eq!(a.indices, b.indices, "{name}");
    }
}

#[test]
fn facade_and_generic_engines_agree_on_workloads() {
    // The compatibility acceptance: the enum facade (service wire format)
    // and the generic trait path return identical CSR output.
    let space = ExecSpace::with_threads(4);
    let (cloud, boxes, _brute) = scene(Shape::FilledCube, 3000, 77);
    let bvh = Bvh::build(&space, &boxes);
    let mut rng = Rng::new(99);
    let centers: Vec<Point> =
        (0..200).map(|_| random_point(&mut rng, cloud.a)).collect();
    let facade: Vec<QueryPredicate> =
        centers.iter().map(|c| QueryPredicate::intersects_sphere(*c, 2.7)).collect();
    let typed: Vec<IntersectsSphere> =
        centers.iter().map(|c| IntersectsSphere(Sphere::new(*c, 2.7))).collect();
    for opts in [
        QueryOptions { buffer_size: None, sort_queries: true },
        QueryOptions { buffer_size: Some(8), sort_queries: true },
        QueryOptions { buffer_size: None, sort_queries: false },
    ] {
        let a = bvh.query(&space, &facade, &opts);
        let b = bvh.query_spatial(&space, &typed, &opts);
        assert_eq!(a.offsets, b.offsets);
        for qi in 0..centers.len() {
            let mut ra = a.results_for(qi).to_vec();
            let mut rb = b.results_for(qi).to_vec();
            ra.sort();
            rb.sort();
            assert_eq!(ra, rb, "query {qi}");
        }
    }
}
