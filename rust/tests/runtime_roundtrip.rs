//! Runtime integration: the AOT artifacts loaded through PJRT must agree
//! with the pure-rust implementations — the cross-language correctness
//! anchor of the three-layer architecture.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a loud message) when the artifact directory is absent so plain
//! `cargo test` works in a fresh checkout. The whole file is gated on the
//! `accel` feature (the PJRT runtime's `xla`/`anyhow` dependencies are
//! not available in the offline build environment).
#![cfg(feature = "accel")]

use std::path::PathBuf;

use arbor::baselines::brute::BruteForce;
use arbor::data::rng::Rng;
use arbor::data::shapes::{PointCloud, Shape};
use arbor::geometry::{morton, Aabb, Point};
use arbor::runtime::AccelEngine;

fn artifact_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("ARBOR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn cloud(n: usize, seed: u64) -> Vec<Point> {
    let mut r = Rng::new(seed);
    (0..n)
        .map(|_| Point::new(r.uniform(-7.0, 7.0), r.uniform(-7.0, 7.0), r.uniform(-7.0, 7.0)))
        .collect()
}

#[test]
fn accel_knn_matches_brute_force() {
    let Some(dir) = artifact_dir() else { return };
    let engine = AccelEngine::new(&dir).expect("load artifacts");
    // Sizes straddle the tile boundaries (q=512, p=4096) to exercise
    // padding and multi-tile merging.
    let queries = cloud(700, 1);
    let points = cloud(5000, 2);
    let boxes: Vec<Aabb> = points.iter().map(|p| Aabb::from_point(*p)).collect();
    let bf = BruteForce::new(&boxes);
    let got = engine.batch_knn(&queries, &points, 10).expect("accel knn");
    assert_eq!(got.len(), queries.len());
    for (qi, q) in queries.iter().enumerate() {
        let want = bf.nearest(q, 10);
        let gd: Vec<f32> = got[qi].iter().map(|n| n.distance_squared).collect();
        let wd: Vec<f32> = want.iter().map(|n| n.distance_squared).collect();
        for (g, w) in gd.iter().zip(&wd) {
            assert!(
                (g - w).abs() <= 1e-3 * w.max(1.0),
                "q{qi}: {gd:?} vs {wd:?} (fp32 matmul-trick tolerance)"
            );
        }
    }
}

#[test]
fn accel_radius_counts_match_brute_force() {
    let Some(dir) = artifact_dir() else { return };
    let engine = AccelEngine::new(&dir).expect("load artifacts");
    let queries = cloud(600, 3);
    let points = cloud(9000, 4);
    let boxes: Vec<Aabb> = points.iter().map(|p| Aabb::from_point(*p)).collect();
    let bf = BruteForce::new(&boxes);
    let preds: Vec<arbor::geometry::predicates::Spatial> = queries
        .iter()
        .map(|q| {
            arbor::geometry::predicates::Spatial::IntersectsSphere(arbor::geometry::Sphere::new(
                *q, 2.0,
            ))
        })
        .collect();
    let got = engine.batch_radius_count(&queries, &points, 2.0).expect("accel radius");
    let want = bf.batch_spatial_counts(&arbor::exec::ExecSpace::serial(), &preds);
    // fp32 boundary effects can flip points sitting exactly at the radius;
    // allow a tiny discrepancy count.
    let mismatches = got.iter().zip(&want).filter(|(a, b)| a != b).count();
    assert!(
        mismatches <= queries.len() / 100,
        "{mismatches} of {} counts disagree",
        queries.len()
    );
}

#[test]
fn accel_morton_codes_match_rust_implementation() {
    let Some(dir) = artifact_dir() else { return };
    let engine = AccelEngine::new(&dir).expect("load artifacts");
    let points = cloud(4096, 5);
    let got = engine.morton_codes(&points).expect("accel morton");

    // Rust-side scene box + codes.
    let mut scene = Aabb::empty();
    for p in &points {
        scene.expand_point(p);
    }
    for (i, p) in points.iter().enumerate() {
        let want = morton::morton32_scene(&Aabb::from_point(*p), &scene);
        assert_eq!(got[i], want, "point {i} ({p:?})");
    }
}

#[test]
fn accel_handles_partial_tiles() {
    let Some(dir) = artifact_dir() else { return };
    let engine = AccelEngine::new(&dir).expect("load artifacts");
    // 3 queries, 5 points: everything is padding except a sliver.
    let queries = cloud(3, 6);
    let points = cloud(5, 7);
    let boxes: Vec<Aabb> = points.iter().map(|p| Aabb::from_point(*p)).collect();
    let bf = BruteForce::new(&boxes);
    let got = engine.batch_knn(&queries, &points, 5).expect("partial tile knn");
    for (qi, q) in queries.iter().enumerate() {
        assert_eq!(got[qi].len(), 5, "all 5 real points returned, no sentinels");
        let want = bf.nearest(q, 5);
        for (g, w) in got[qi].iter().zip(&want) {
            assert!((g.distance_squared - w.distance_squared).abs() <= 1e-3);
        }
    }
}

#[test]
fn accel_workload_smoke_filled_case() {
    // The Figure-10 configuration in miniature: filled sphere targets in
    // a filled cube source through the accelerator.
    let Some(dir) = artifact_dir() else { return };
    let engine = AccelEngine::new(&dir).expect("load artifacts");
    let sources = PointCloud::generate(Shape::FilledCube, 8192, 8);
    let targets = PointCloud::generate(Shape::FilledSphere, 512, 9);
    let counts = engine
        .batch_radius_count(
            &targets.points,
            &sources.points,
            arbor::data::workloads::spatial_radius(10),
        )
        .expect("radius counts");
    let avg = counts.iter().map(|&c| c as f64).sum::<f64>() / counts.len() as f64;
    assert!((5.0..15.0).contains(&avg), "filled-case calibration: avg {avg} ~ 10");
}
