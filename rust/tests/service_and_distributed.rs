//! Integration tests across the coordinator layer: the batched service
//! and the simulated distributed tree against direct batched queries,
//! including the service-vs-direct differential over every wire
//! predicate kind, the adaptive-buffer regression for the §3.2
//! hollow-sphere pathology, and the fixed-histogram behavior under a
//! non-stationary workload.

mod common;

use std::sync::Arc;

use arbor::bvh::{Bvh, PredicateKind, QueryOptions, QueryPredicate};
use arbor::coordinator::distributed::{DistributedTree, Partition};
use arbor::coordinator::metrics::{ADAPTIVE_MAX_BUFFER, ADAPTIVE_MIN_SAMPLES, Metrics};
use arbor::coordinator::service::{
    execute_sub_batched, BufferPolicy, QueryError, SearchService, ServiceConfig, SubmitError,
};
use arbor::data::shapes::Shape;
use arbor::data::workloads::{spatial_radius, Case, Workload};
use arbor::exec::ExecSpace;
use arbor::geometry::predicates::{
    attach, IntersectsBox, IntersectsRay, IntersectsSphere, Spatial, WithData,
};
use arbor::geometry::{Aabb, Point, Ray, Sphere};

use common::{scene, sorted};

#[test]
fn service_results_equal_direct_batched_queries() {
    let space = ExecSpace::with_threads(2);
    let w = Workload::generate(Case::Filled, 10_000, 500, 21);
    let bvh = Arc::new(Bvh::build(&space, &w.sources.boxes()));
    let direct = bvh.query(&space, &w.spatial, &QueryOptions::default());

    let svc = SearchService::start(Arc::clone(&bvh), ServiceConfig::default());
    // Submit everything first so the batcher can coalesce, then await.
    let pendings: Vec<_> =
        w.spatial.iter().map(|p| svc.submit(*p).expect("service running")).collect();
    for (qi, pending) in pendings.into_iter().enumerate() {
        let mut got = pending.wait().expect("answered").indices;
        got.sort();
        let mut want = direct.results_for(qi).to_vec();
        want.sort();
        assert_eq!(got, want, "query {qi}");
    }
    assert_eq!(svc.metrics().requests(), w.spatial.len() as u64);
    assert!(svc.metrics().batches() < w.spatial.len() as u64, "batching happened");
    let (p50, _, p99) = svc.metrics().latency_quantiles();
    assert!(p50 <= p99);
}

#[test]
fn distributed_tree_equals_single_tree_on_workload() {
    let space = ExecSpace::with_threads(2);
    let w = Workload::generate(Case::Filled, 12_000, 12_000, 23);
    let boxes = w.sources.boxes();
    let single = Bvh::build(&space, &boxes);
    let dist = DistributedTree::build(&space, &boxes, 6, Partition::MortonBlock);

    let r = spatial_radius(10);
    let single_out = {
        let queries: Vec<QueryPredicate> = w.targets.points[..200]
            .iter()
            .map(|p| QueryPredicate::intersects_sphere(*p, r))
            .collect();
        single.query(&space, &queries, &QueryOptions::default())
    };
    for (qi, p) in w.targets.points[..200].iter().enumerate() {
        let pred = Spatial::IntersectsSphere(Sphere::new(*p, r));
        let (got, stats) = dist.spatial(&pred);
        let mut want = single_out.results_for(qi).to_vec();
        want.sort();
        assert_eq!(got, want, "query {qi}");
        assert!(stats.ranks_contacted <= dist.n_ranks());
    }
}

#[test]
fn service_handles_hollow_imbalance() {
    // The hollow case's wild per-query imbalance must not wedge the
    // batcher (most queries empty, some returning hundreds). A static
    // buffer of 1 mass-overflows into the fallback second pass; the
    // adaptive policy returns identical results on the same load.
    let space = ExecSpace::with_threads(2);
    let w = Workload::generate(Case::Hollow, 20_000, 1_000, 29);
    let bvh = Arc::new(Bvh::build(&space, &w.sources.boxes()));
    let direct = bvh.query(&space, &w.spatial, &QueryOptions::default());
    let max = (0..w.spatial.len()).map(|q| direct.results_for(q).len()).max().unwrap();
    assert!(max > 1, "hollow workload must be imbalanced (max {max})");

    let static_svc = SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig {
            max_batch: 128,
            buffer_policy: BufferPolicy::Static(1),
            ..Default::default()
        },
    );
    let pendings: Vec<_> =
        w.spatial.iter().map(|p| static_svc.submit(*p).expect("service running")).collect();
    let total: usize =
        pendings.into_iter().map(|p| p.wait().expect("answered").indices.len()).sum();
    // n != m here, so the calibration doesn't hold; require progress,
    // consistency with metrics, and the §3.2 second-pass signature.
    assert_eq!(static_svc.metrics().results(), total as u64);
    assert!(static_svc.metrics().fallback_batches() > 0, "static(1) must fall back");
    assert!(static_svc.metrics().overflowed_queries() > 0);
    static_svc.shutdown();

    let adaptive_svc = SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig { max_batch: 128, ..Default::default() },
    );
    let pendings: Vec<_> =
        w.spatial.iter().map(|p| adaptive_svc.submit(*p).expect("service running")).collect();
    for (qi, pending) in pendings.into_iter().enumerate() {
        let mut got = pending.wait().expect("answered").indices;
        got.sort();
        let mut want = direct.results_for(qi).to_vec();
        want.sort();
        assert_eq!(got, want, "query {qi}");
    }
    let suggested = adaptive_svc.metrics().suggest_buffer(PredicateKind::Sphere);
    assert!(suggested.is_some_and(|b| b <= ADAPTIVE_MAX_BUFFER), "{suggested:?}");
}

/// Builds a mixed wire batch covering every predicate kind, round-robin
/// over `points`.
fn mixed_wire_batch(points: &[Point], radius: f32) -> Vec<QueryPredicate> {
    points
        .iter()
        .enumerate()
        .map(|(i, p)| match i % 9 {
            0 => QueryPredicate::intersects_sphere(*p, radius),
            1 => QueryPredicate::intersects_box(Aabb::new(
                Point::new(p[0] - radius, p[1] - radius, p[2] - radius),
                Point::new(p[0] + radius, p[1] + radius, p[2] + radius),
            )),
            2 => QueryPredicate::intersects_ray(Ray::new(*p, Point::new(0.3, 1.0, -0.2))),
            3 => QueryPredicate::attach(
                Spatial::IntersectsSphere(Sphere::new(*p, radius)),
                i as u64,
            ),
            4 => QueryPredicate::attach(
                Spatial::IntersectsRay(Ray::new(*p, Point::new(-1.0, 0.4, 0.1))),
                i as u64,
            ),
            5 => QueryPredicate::nearest(*p, 7),
            6 => QueryPredicate::nearest_sphere(Sphere::new(*p, radius), 7),
            7 => QueryPredicate::nearest_box(
                Aabb::new(
                    Point::new(p[0] - radius, p[1] - radius, p[2] - radius),
                    Point::new(p[0] + radius, p[1] + radius, p[2] + radius),
                ),
                7,
            ),
            // An axis ray starting on the point itself: a guaranteed
            // first hit at t = 0.
            _ => QueryPredicate::first_hit(Ray::new(*p, Point::new(0.0, 0.0, 1.0))),
        })
        .collect()
}

/// Direct (service-free) ground truth for one wire predicate: spatial
/// kinds through the monomorphized `Bvh::query_spatial`, the nearest and
/// first-hit families through the facade.
fn direct_one(bvh: &Bvh, space: &ExecSpace, pred: &QueryPredicate) -> (Vec<u32>, Vec<f32>) {
    let opts = QueryOptions::default();
    match pred {
        QueryPredicate::Spatial(s) | QueryPredicate::Attach(s, _) => {
            let out = match s {
                Spatial::IntersectsSphere(sp) => {
                    bvh.query_spatial(space, &[IntersectsSphere(*sp)], &opts)
                }
                Spatial::IntersectsBox(b) => {
                    bvh.query_spatial(space, &[IntersectsBox(*b)], &opts)
                }
                Spatial::IntersectsRay(r) => {
                    bvh.query_spatial(space, &[IntersectsRay(*r)], &opts)
                }
            };
            (out.results_for(0).to_vec(), Vec::new())
        }
        QueryPredicate::Nearest(_)
        | QueryPredicate::NearestSphere(_)
        | QueryPredicate::NearestBox(_)
        | QueryPredicate::FirstHit(_) => {
            let out = bvh.query(space, &[*pred], &opts);
            (out.results_for(0).to_vec(), out.distances_for(0).to_vec())
        }
    }
}

#[test]
fn service_differential_every_wire_kind_under_concurrency() {
    // Acceptance: every wire kind (sphere, box, ray, attach, the nearest
    // point/sphere/box family, first-hit) submitted through the service
    // under concurrent submitters returns results equal to direct
    // Bvh::query_spatial on the same data, including mixed-kind
    // interleavings that force sub-batch splits.
    let space = ExecSpace::with_threads(4);
    let (cloud, boxes, _brute) = scene(Shape::FilledCube, 6_000, 13);
    let bvh = Arc::new(Bvh::build(&space, &boxes));
    let radius = spatial_radius(10);
    let preds = mixed_wire_batch(&cloud.points[..960], radius);
    // WithData flows through the generic engine identically to its inner
    // predicate — anchor one attachment against its typed twin.
    let typed_attach: Vec<WithData<IntersectsSphere, u64>> = match &preds[3] {
        QueryPredicate::Attach(Spatial::IntersectsSphere(s), d) => {
            vec![attach(IntersectsSphere(*s), *d)]
        }
        other => panic!("slot 3 must be attach_sphere, got {other:?}"),
    };
    let typed_out = bvh.query_spatial(&space, &typed_attach, &QueryOptions::default());
    assert_eq!(typed_out.results_for(0), direct_one(&bvh, &space, &preds[3]).0);

    let want: Vec<(Vec<u32>, Vec<f32>)> =
        preds.iter().map(|p| direct_one(&bvh, &space, p)).collect();

    // Small batches force splits across mixed-kind boundaries.
    let svc = Arc::new(SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig { max_batch: 64, threads: 2, ..Default::default() },
    ));
    let submitters = 4;
    let mut handles = Vec::new();
    for t in 0..submitters {
        let svc = Arc::clone(&svc);
        let preds = preds.clone();
        handles.push(std::thread::spawn(move || {
            // Strided slices keep each thread's stream mixed-kind.
            let pendings: Vec<_> = (t..preds.len())
                .step_by(submitters)
                .map(|i| (i, svc.submit(preds[i]).expect("service running")))
                .collect();
            pendings
                .into_iter()
                .map(|(i, p)| (i, p.wait().expect("answered")))
                .collect::<Vec<_>>()
        }));
    }
    let mut seen = 0usize;
    for h in handles {
        for (i, r) in h.join().unwrap() {
            seen += 1;
            let (want_idx, want_dist) = &want[i];
            assert_eq!(
                sorted(r.indices.clone()),
                sorted(want_idx.clone()),
                "query {i} ({:?})",
                preds[i].kind()
            );
            if matches!(
                preds[i].kind(),
                PredicateKind::Nearest
                    | PredicateKind::NearestSphere
                    | PredicateKind::NearestBox
                    | PredicateKind::FirstHit
            ) {
                assert_eq!(r.indices, *want_idx, "ordered result {i}");
                assert_eq!(r.distances, *want_dist, "result distances {i}");
            }
            assert_eq!(r.data, preds[i].data(), "payload {i}");
        }
    }
    assert_eq!(seen, preds.len());
    assert_eq!(svc.metrics().requests(), preds.len() as u64);
    assert!(svc.metrics().batches() >= (preds.len() / 64) as u64, "max_batch respected");
}

#[test]
fn adaptive_buffer_regression_hollow_style() {
    // Modeled on the §3.2 hollow-sphere pathology: almost every query
    // returns one result while a 2% tail returns ~600, so a static small
    // buffer mass-overflows into the fallback second pass and a static
    // max-sized buffer is the prohibitive allocation the paper reports.
    // The adaptive policy must converge to a buffer that covers the tail
    // (no fallback) while staying capped.
    let space = ExecSpace::with_threads(2);
    let points: Vec<Point> = (0..4096).map(|i| Point::new(i as f32, 0.0, 0.0)).collect();
    let boxes: Vec<Aabb> = points.iter().map(|p| Aabb::from_point(*p)).collect();
    let bvh = Arc::new(Bvh::build(&space, &boxes));
    let monster = QueryPredicate::intersects_sphere(Point::new(2048.0, 0.0, 0.0), 300.0);
    let preds: Vec<QueryPredicate> = (0..5000)
        .map(|i| {
            if i % 50 == 0 {
                monster
            } else {
                QueryPredicate::intersects_sphere(Point::new((i % 4096) as f32, 0.0, 0.0), 0.4)
            }
        })
        .collect();
    let direct = bvh.query(&space, &preds, &QueryOptions::default());
    let max_count = (0..preds.len()).map(|q| direct.results_for(q).len()).max().unwrap();
    assert_eq!(max_count, 601, "the monster spans [1748, 2348]");

    let run = |svc: &SearchService| -> usize {
        let pendings: Vec<_> =
            preds.iter().map(|p| svc.submit(*p).expect("service running")).collect();
        pendings.into_iter().map(|p| p.wait().expect("answered").indices.len()).sum()
    };

    // The static mis-sized buffer takes the fallback second pass.
    let static_svc = SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig {
            max_batch: 256,
            buffer_policy: BufferPolicy::Static(8),
            threads: 2,
            ..Default::default()
        },
    );
    let static_total = run(&static_svc);
    assert_eq!(static_total, direct.total());
    assert!(static_svc.metrics().fallback_batches() > 0, "static(8) must take the 2nd pass");
    assert!(static_svc.metrics().overflowed_queries() > 0);
    assert_eq!(static_svc.metrics().two_pass_batches(), 0);
    static_svc.shutdown();

    // Adaptive: cold sub-batches run 2P, then the percentile buffer
    // covers the tail.
    let svc = SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig { max_batch: 256, threads: 2, ..Default::default() },
    );
    let adaptive_total = run(&svc);
    assert_eq!(adaptive_total, static_total, "strategies agree on results");
    assert!(svc.metrics().two_pass_batches() > 0, "cold start ran 2P");
    let hist_samples = svc.metrics().result_histogram(PredicateKind::Sphere).samples();
    assert!(hist_samples >= ADAPTIVE_MIN_SAMPLES.max(5000));
    let suggested = svc.metrics().suggest_buffer(PredicateKind::Sphere).expect("warmed up");
    assert!(
        suggested >= max_count,
        "converged buffer {suggested} must cover the worst query ({max_count})"
    );
    assert!(suggested <= ADAPTIVE_MAX_BUFFER);

    // Steady state: a second identical round takes no fallback pass and
    // runs single-pass.
    let fallback_before = svc.metrics().fallback_batches();
    let one_pass_before = svc.metrics().one_pass_batches();
    run(&svc);
    assert_eq!(
        svc.metrics().fallback_batches(),
        fallback_before,
        "adaptive steady state avoids the fallback second pass"
    );
    assert!(svc.metrics().one_pass_batches() > one_pass_before, "warm sub-batches run 1P");
}

#[test]
fn distributed_rank_counts_scale() {
    let space = ExecSpace::serial();
    let (_cloud, boxes, _brute) = scene(Shape::FilledCube, 5000, 31);
    for ranks in [1usize, 2, 4, 16] {
        let dt = DistributedTree::build(&space, &boxes, ranks, Partition::MortonBlock);
        assert_eq!(dt.n_ranks(), ranks.min(5000));
        assert_eq!(dt.len(), 5000);
        // Balanced: shard sizes differ by at most one, none empty.
        let sizes: Vec<usize> = (0..dt.n_ranks()).map(|r| dt.rank_len(r)).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(*min >= 1 && max - min <= 1, "unbalanced shards {sizes:?}");
    }
    // The exact acceptance shape: 6 objects over 4 requested ranks must
    // give 4 ranks (the ceiling-division chunking used to give 3).
    let dt = DistributedTree::build(&space, &boxes[..6], 4, Partition::Block);
    assert_eq!(dt.n_ranks(), 4);
}

#[test]
fn service_shutdown_with_in_flight_queries_is_panic_free() {
    // Regression for the satellite bugfix: submit used to
    // `expect("service stopped")` and wait used to panic when the
    // service dropped the channel. Now shutdown drains accepted work,
    // answers it, and refuses new work with an error.
    let space = ExecSpace::serial();
    let (_cloud, boxes, _brute) = scene(Shape::FilledCube, 2000, 91);
    let bvh = Arc::new(Bvh::build(&space, &boxes));
    let svc = Arc::new(SearchService::start(
        Arc::clone(&bvh),
        ServiceConfig { max_batch: 32, ..Default::default() },
    ));
    // A racing submitter thread: every submission either succeeds (and
    // must then be answered) or reports Stopped — never a panic.
    let racer = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            let mut answered = 0usize;
            let mut stopped = 0usize;
            for i in 0..5000 {
                match svc.submit(QueryPredicate::nearest(
                    Point::new((i % 100) as f32 * 0.1, 0.0, 0.0),
                    2,
                )) {
                    Ok(p) => {
                        p.wait().expect("accepted request must be drained");
                        answered += 1;
                    }
                    Err(SubmitError::Stopped) => {
                        stopped += 1;
                        break;
                    }
                    Err(e) => panic!("unexpected submit error {e:?}"),
                }
            }
            (answered, stopped)
        })
    };
    // Let the racer get at least one answer, then pull the rug.
    let t0 = std::time::Instant::now();
    while svc.metrics().requests() == 0 && t0.elapsed().as_secs() < 10 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    svc.shutdown();
    let (answered, _stopped) = racer.join().expect("no panic anywhere in the race");
    assert!(answered >= 1, "some requests were served before the stop");
    // After shutdown every entry point reports an error, not a panic.
    assert_eq!(
        svc.submit(QueryPredicate::nearest(Point::origin(), 1)).err(),
        Some(SubmitError::Stopped)
    );
    assert_eq!(
        svc.query(QueryPredicate::nearest(Point::origin(), 1)).err(),
        Some(QueryError::Stopped)
    );
}

#[test]
fn adaptive_buffer_tracks_a_nonstationary_shift() {
    // When the result-count distribution shifts mid-run (small results,
    // then a heavy regime), the Adaptive policy must reach a steady state
    // that is not perpetual one-pass-fallback: the 0.999 quantile jumps to
    // the new regime as soon as the post-shift samples exceed ~0.1% of
    // the active window, so at most the first post-shift sub-batches fall
    // back.
    //
    // The reverse shift (heavy -> light) is pinned below: the windowed
    // histograms (ROADMAP 5a) retire the heavy epoch after two window
    // rotations of light traffic, so the buffer *shrinks back* instead of
    // keeping the oversized allocation forever as the old fixed
    // histograms did.
    let space = ExecSpace::with_threads(2);
    let points: Vec<Point> = (0..4096).map(|i| Point::new(i as f32, 0.0, 0.0)).collect();
    let boxes: Vec<Aabb> = points.iter().map(|p| Aabb::from_point(*p)).collect();
    let bvh = Bvh::build(&space, &boxes);
    let metrics = Metrics::default();
    let batch_of = |radius: f32| -> Vec<QueryPredicate> {
        (0..256)
            .map(|i| {
                QueryPredicate::intersects_sphere(
                    Point::new(((i * 16) % 4096) as f32, 0.0, 0.0),
                    radius,
                )
            })
            .collect()
    };
    let run = |preds: &[QueryPredicate], metrics: &Metrics| {
        let out =
            execute_sub_batched(&bvh, &space, preds, BufferPolicy::Adaptive, true, metrics);
        assert_eq!(out.len(), preds.len());
    };

    // Phase A: light regime (radius 0.4 -> exactly 1 result per query).
    for _ in 0..4 {
        run(&batch_of(0.4), &metrics);
    }
    assert!(metrics.two_pass_batches() >= 1, "cold start runs 2P");
    let light = metrics.suggest_buffer(PredicateKind::Sphere).expect("warmed up");
    assert!(light < 64, "light-regime buffer should be small, got {light}");

    // Phase B: the distribution shifts — radius 40 spheres return ~80
    // results, far beyond the light-regime buffer. The first post-shift
    // sub-batch overflows into the fallback second pass...
    run(&batch_of(40.0), &metrics);
    assert!(metrics.fallback_batches() >= 1, "shift must trip the fallback once");
    let fallback_after_shift = metrics.fallback_batches();
    // ...but the histogram has already absorbed the new tail, so the
    // suggestion covers it and the steady state is fallback-free 1P.
    let heavy = metrics.suggest_buffer(PredicateKind::Sphere).expect("still warm");
    assert!(heavy >= 81, "post-shift buffer {heavy} must cover the new regime");
    let one_pass_before = metrics.one_pass_batches();
    for _ in 0..6 {
        run(&batch_of(40.0), &metrics);
    }
    assert_eq!(
        metrics.fallback_batches(),
        fallback_after_shift,
        "steady state after the shift must not keep falling back"
    );
    assert!(metrics.one_pass_batches() >= one_pass_before + 6, "heavy regime runs 1P");

    // Shift back down: twelve light batches (3072 samples, two-plus full
    // windows of ADAPTIVE_WINDOW = 1024) rotate the heavy epoch out of
    // the histogram entirely. Nothing falls back or reverts to 2P on the
    // way down — light queries fit any buffer — and the suggestion
    // deflates to the light-regime size instead of keeping the heavy
    // allocation forever.
    for _ in 0..12 {
        run(&batch_of(0.4), &metrics);
    }
    assert_eq!(metrics.fallback_batches(), fallback_after_shift);
    let settled = metrics.suggest_buffer(PredicateKind::Sphere).expect("warm");
    assert!(
        settled < 64,
        "windowed histograms must shrink the buffer after a downshift, got {settled}"
    );
    assert!(settled >= 1, "suggestion stays usable ({settled})");
}
