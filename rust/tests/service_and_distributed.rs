//! Integration tests across the coordinator layer: the batched service
//! and the simulated distributed tree against direct batched queries.

use std::sync::Arc;

use arbor::bvh::{Bvh, QueryOptions, QueryPredicate};
use arbor::coordinator::distributed::{DistributedTree, Partition};
use arbor::coordinator::service::{SearchService, ServiceConfig};
use arbor::data::shapes::{PointCloud, Shape};
use arbor::data::workloads::{spatial_radius, Case, Workload};
use arbor::exec::ExecSpace;
use arbor::geometry::predicates::Spatial;
use arbor::geometry::Sphere;

#[test]
fn service_results_equal_direct_batched_queries() {
    let space = ExecSpace::with_threads(2);
    let w = Workload::generate(Case::Filled, 10_000, 500, 21);
    let bvh = Arc::new(Bvh::build(&space, &w.sources.boxes()));
    let direct = bvh.query(&space, &w.spatial, &QueryOptions::default());

    let svc = SearchService::start(Arc::clone(&bvh), ServiceConfig::default());
    // Submit everything first so the batcher can coalesce, then await.
    let pendings: Vec<_> = w.spatial.iter().map(|p| svc.submit(*p)).collect();
    for (qi, pending) in pendings.into_iter().enumerate() {
        let mut got = pending.wait().indices;
        got.sort();
        let mut want = direct.results_for(qi).to_vec();
        want.sort();
        assert_eq!(got, want, "query {qi}");
    }
    assert_eq!(svc.metrics().requests(), w.spatial.len() as u64);
    assert!(svc.metrics().batches() < w.spatial.len() as u64, "batching happened");
    let (p50, _, p99) = svc.metrics().latency_quantiles();
    assert!(p50 <= p99);
}

#[test]
fn distributed_tree_equals_single_tree_on_workload() {
    let space = ExecSpace::with_threads(2);
    let w = Workload::generate(Case::Filled, 12_000, 12_000, 23);
    let boxes = w.sources.boxes();
    let single = Bvh::build(&space, &boxes);
    let dist = DistributedTree::build(&space, &boxes, 6, Partition::MortonBlock);

    let r = spatial_radius(10);
    let single_out = {
        let queries: Vec<QueryPredicate> = w.targets.points[..200]
            .iter()
            .map(|p| QueryPredicate::intersects_sphere(*p, r))
            .collect();
        single.query(&space, &queries, &QueryOptions::default())
    };
    for (qi, p) in w.targets.points[..200].iter().enumerate() {
        let pred = Spatial::IntersectsSphere(Sphere::new(*p, r));
        let (got, stats) = dist.spatial(&pred);
        let mut want = single_out.results_for(qi).to_vec();
        want.sort();
        assert_eq!(got, want, "query {qi}");
        assert!(stats.ranks_contacted <= dist.n_ranks());
    }
}

#[test]
fn service_handles_hollow_imbalance() {
    // The hollow case's wild per-query imbalance must not wedge the
    // batcher (most queries empty, some returning hundreds).
    let space = ExecSpace::with_threads(2);
    let w = Workload::generate(Case::Hollow, 20_000, 1_000, 29);
    let bvh = Arc::new(Bvh::build(&space, &w.sources.boxes()));
    let svc = SearchService::start(
        bvh,
        ServiceConfig { max_batch: 128, ..Default::default() },
    );
    let pendings: Vec<_> = w.spatial.iter().map(|p| svc.submit(*p)).collect();
    let total: usize = pendings.into_iter().map(|p| p.wait().indices.len()).sum();
    // n != m here, so the calibration doesn't hold; just require progress
    // and consistency with metrics.
    assert_eq!(svc.metrics().results(), total as u64);
}

#[test]
fn distributed_rank_counts_scale() {
    let space = ExecSpace::serial();
    let cloud = PointCloud::generate(Shape::FilledCube, 5000, 31);
    for ranks in [1usize, 2, 4, 16] {
        let dt = DistributedTree::build(&space, &cloud.boxes(), ranks, Partition::MortonBlock);
        assert_eq!(dt.n_ranks(), ranks.min(5000));
        assert_eq!(dt.len(), 5000);
    }
}
