//! Loopback differential suite for the TCP / Unix-socket front end.
//!
//! N concurrent clients drive all 10 wire kinds through a real
//! `NetServer` on 127.0.0.1 (and a Unix socket) and every response row
//! must equal the direct `Bvh` answer on the same tree — sorted
//! canonicalization for the unordered spatial kinds, exact row equality
//! for the deterministic nearest / first-hit kinds, attachment payloads
//! echoed. The suite also pins the failure semantics end to end: a
//! malformed body rejects its whole frame but the connection survives;
//! a framing violation closes the offending connection without
//! disturbing others; a truncated frame at EOF counts as malformed;
//! mid-connection service shutdown answers clean `STATUS_STOPPED`
//! error frames and EOF, never a hang; and a pipelining client that
//! outruns its reads trips the bounded in-flight window (a recorded
//! backpressure stall), not the batcher.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use arbor::bvh::QueryPredicate;
use arbor::coordinator::wire::{
    self, wire_tag, MAX_FRAME_LEN, STATUS_MALFORMED, STATUS_OK, STATUS_STOPPED,
};
use arbor::prelude::*;

use common::{scene, wire_batch};

/// A service over an inflated scene (finite extents so rays and boxes
/// genuinely overlap), plus the tree for direct-answer oracles.
fn net_fixture(
    n: usize,
    max_batch: usize,
    batch_timeout: Duration,
) -> (Arc<SearchService>, Arc<Bvh>, ExecSpace, PointCloud) {
    let space = ExecSpace::with_threads(2);
    let (cloud, _, _) = scene(Shape::FilledCube, n, 1109);
    let boxes = common::inflate(&cloud, 0.4);
    let bvh = Arc::new(Bvh::build(&space, &boxes));
    let config = ServiceConfig { max_batch, batch_timeout, threads: 2, ..Default::default() };
    let svc = Arc::new(SearchService::start(Arc::clone(&bvh), config));
    (svc, bvh, space, cloud)
}

/// Is this an unordered (spatial) row — compared as a sorted set?
fn is_spatial(pred: &QueryPredicate) -> bool {
    matches!(pred, QueryPredicate::Spatial(_) | QueryPredicate::Attach(..))
}

/// The attachment payload a response must echo for this predicate.
fn attach_data(pred: &QueryPredicate) -> Option<u64> {
    match pred {
        QueryPredicate::Attach(_, d) => Some(*d),
        _ => None,
    }
}

/// Direct per-query answers on the same tree, canonicalized for
/// comparison: (indices, distances, data) per row, spatial rows sorted.
fn expected_rows(
    bvh: &Bvh,
    space: &ExecSpace,
    preds: &[QueryPredicate],
) -> Vec<(Vec<u32>, Vec<f32>, Option<u64>)> {
    let out = bvh.query(space, preds, &QueryOptions::default());
    preds
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut indices = out.results_for(i).to_vec();
            // The service ships distances only for the ordered kinds
            // (nearest / first-hit); spatial rows travel without them.
            let distances =
                if is_spatial(p) { Vec::new() } else { out.distances_for(i).to_vec() };
            if is_spatial(p) {
                indices.sort();
            }
            (indices, distances, attach_data(p))
        })
        .collect()
}

/// Asserts one response against the expectations for its frame.
fn check_response(
    label: &str,
    response: &NetResponse,
    preds: &[QueryPredicate],
    expected: &[(Vec<u32>, Vec<f32>, Option<u64>)],
) {
    assert_eq!(response.status, STATUS_OK, "{label}: status");
    assert_eq!(response.results.len(), preds.len(), "{label}: result count");
    for (qi, (result, pred)) in response.results.iter().zip(preds).enumerate() {
        assert_eq!(result.tag, wire_tag(pred), "{label} q{qi}: tag echo");
        let (want_idx, want_dist, want_data) = &expected[qi];
        let mut got_idx = result.indices.clone();
        if is_spatial(pred) {
            got_idx.sort();
        }
        assert_eq!(&got_idx, want_idx, "{label} q{qi}: indices ({pred:?})");
        assert_eq!(&result.distances, want_dist, "{label} q{qi}: distances");
        assert_eq!(&result.data, want_data, "{label} q{qi}: attach payload");
    }
}

#[test]
fn concurrent_tcp_clients_match_direct_queries_across_all_kinds() {
    let (svc, bvh, space, cloud) = net_fixture(4000, 64, Duration::from_millis(1));
    let mut server = NetServer::bind_tcp(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetConfig { max_in_flight: 8, ..Default::default() },
    )
    .expect("bind");
    let addr = server.local_addr().expect("tcp addr");

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 40; // 4 frames x 10 predicates, all 10 kinds
    const FRAME: usize = 10;
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let anchors = &cloud.points[c * PER_CLIENT..(c + 1) * PER_CLIENT];
        let preds = wire_batch(anchors, 1.1, 5);
        let expected = expected_rows(&bvh, &space, &preds);
        handles.push(std::thread::spawn(move || {
            let mut client = NetClient::connect_tcp(addr).expect("connect");
            // Pipeline all frames before reading any response.
            let ids: Vec<u64> =
                preds.chunks(FRAME).map(|chunk| client.submit(chunk).expect("submit")).collect();
            for (f, id) in ids.iter().enumerate() {
                let response = client.receive().expect("response");
                assert_eq!(response.request_id, *id, "client {c}: pipelined order");
                check_response(
                    &format!("client {c} frame {f}"),
                    &response,
                    &preds[f * FRAME..(f + 1) * FRAME],
                    &expected[f * FRAME..(f + 1) * FRAME],
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }

    let metrics = svc.metrics();
    assert_eq!(metrics.net_connections(), CLIENTS as u64);
    assert_eq!(metrics.net_frames(), (CLIENTS * PER_CLIENT / FRAME) as u64);
    assert_eq!(metrics.net_malformed_frames(), 0);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn malformed_body_rejects_the_frame_but_the_connection_survives() {
    let (svc, bvh, space, cloud) = net_fixture(500, 16, Duration::from_millis(1));
    let mut server =
        NetServer::bind_tcp(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).expect("bind");
    let addr = server.local_addr().expect("tcp addr");
    let mut client = NetClient::connect_tcp(addr).expect("connect");

    // A frame whose body is two good predicates followed by garbage:
    // decode_batch refuses it, the whole frame answers STATUS_MALFORMED,
    // and nothing reaches the coordinator.
    let good = wire_batch(&cloud.points[..10], 1.1, 5);
    let mut body = Vec::new();
    wire::encode_batch(&good[..2], &mut body);
    body.push(0x7F);
    let mut frame = Vec::new();
    wire::encode_frame(77, &body, &mut frame);
    client.send_raw(&frame).expect("send");
    let response = client.receive().expect("error frame");
    assert_eq!((response.request_id, response.status), (77, STATUS_MALFORMED));
    assert!(response.results.is_empty());
    assert_eq!(svc.metrics().net_malformed_frames(), 1);
    assert_eq!(svc.metrics().requests(), 0, "rejected frame submits nothing");

    // The framing was never violated, so the same connection keeps
    // serving — and the answers still match direct queries.
    let expected = expected_rows(&bvh, &space, &good);
    let response = client.roundtrip(&good).expect("connection survives");
    check_response("post-reject", &response, &good, &expected);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn framing_violations_close_one_connection_without_touching_others() {
    let (svc, bvh, space, cloud) = net_fixture(500, 16, Duration::from_millis(1));
    let mut server =
        NetServer::bind_tcp(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).expect("bind");
    let addr = server.local_addr().expect("tcp addr");
    let mut bystander = NetClient::connect_tcp(addr).expect("connect bystander");

    // Oversized declaration: the header alone is rejected — the server
    // must answer STATUS_MALFORMED (it has the request id) and close,
    // without ever buffering the declared gigabytes.
    let mut hostile = NetClient::connect_tcp(addr).expect("connect hostile");
    let mut raw = Vec::new();
    raw.extend_from_slice(&((8 + MAX_FRAME_LEN + 1) as u32).to_le_bytes());
    raw.extend_from_slice(&123u64.to_le_bytes());
    hostile.send_raw(&raw).expect("send oversized header");
    let response = hostile.receive().expect("error frame");
    assert_eq!((response.request_id, response.status), (123, STATUS_MALFORMED));
    let eof = hostile.receive().expect_err("connection must close");
    assert_eq!(eof.kind(), std::io::ErrorKind::UnexpectedEof);

    // Zero-length body: same verdict.
    let mut hostile = NetClient::connect_tcp(addr).expect("connect hostile");
    let mut raw = Vec::new();
    raw.extend_from_slice(&8u32.to_le_bytes());
    raw.extend_from_slice(&55u64.to_le_bytes());
    hostile.send_raw(&raw).expect("send zero-length frame");
    let response = hostile.receive().expect("error frame");
    assert_eq!((response.request_id, response.status), (55, STATUS_MALFORMED));
    assert!(matches!(
        hostile.receive().expect_err("connection must close").kind(),
        std::io::ErrorKind::UnexpectedEof
    ));

    // The bystander connection never noticed.
    let preds = wire_batch(&cloud.points[..10], 1.1, 5);
    let expected = expected_rows(&bvh, &space, &preds);
    let response = bystander.roundtrip(&preds).expect("bystander unaffected");
    check_response("bystander", &response, &preds, &expected);
    assert!(svc.metrics().net_malformed_frames() >= 2);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn truncated_frame_at_eof_counts_as_malformed() {
    let (svc, _, _, _) = net_fixture(100, 16, Duration::from_millis(1));
    let mut server =
        NetServer::bind_tcp(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).expect("bind");
    let addr = server.local_addr().expect("tcp addr");
    {
        let mut client = NetClient::connect_tcp(addr).expect("connect");
        // A valid header and id, but the declared body never arrives:
        // dropping the connection leaves a truncated frame.
        let mut raw = Vec::new();
        raw.extend_from_slice(&100u32.to_le_bytes());
        raw.extend_from_slice(&9u64.to_le_bytes());
        raw.extend_from_slice(&[0u8; 16]);
        client.send_raw(&raw).expect("send partial frame");
    } // client dropped -> EOF with buffered partial frame
    let deadline = Instant::now() + Duration::from_secs(5);
    while svc.metrics().net_malformed_frames() == 0 {
        assert!(Instant::now() < deadline, "truncated frame never counted");
        std::thread::sleep(Duration::from_millis(5));
    }
    server.shutdown();
    svc.shutdown();
}

#[test]
fn mid_connection_shutdown_answers_stopped_then_eof() {
    let (svc, bvh, space, cloud) = net_fixture(500, 16, Duration::from_millis(1));
    let mut server =
        NetServer::bind_tcp(Arc::clone(&svc), "127.0.0.1:0", NetConfig::default()).expect("bind");
    let addr = server.local_addr().expect("tcp addr");
    let mut client = NetClient::connect_tcp(addr).expect("connect");

    // Normal traffic first: the connection is live mid-protocol.
    let preds = wire_batch(&cloud.points[..10], 1.1, 5);
    let expected = expected_rows(&bvh, &space, &preds);
    let response = client.roundtrip(&preds).expect("pre-shutdown roundtrip");
    check_response("pre-shutdown", &response, &preds, &expected);

    // Stop the service under the open connection. A frame submitted
    // after the stop rides SubmitError::Stopped into a clean
    // STATUS_STOPPED error frame, then the connection half-closes: the
    // client sees an orderly error + EOF, not a hang or a reset.
    svc.shutdown();
    let id = client.submit(&preds).expect("submit after shutdown");
    let response = client.receive().expect("stopped frame");
    assert_eq!((response.request_id, response.status), (id, STATUS_STOPPED));
    assert!(response.results.is_empty());
    let eof = client.receive().expect_err("clean EOF after drain");
    assert_eq!(eof.kind(), std::io::ErrorKind::UnexpectedEof);
    server.shutdown();
}

#[test]
fn pipelining_past_the_window_stalls_the_reader_not_the_batcher() {
    // max_batch is huge and the batch timeout long, so responses are
    // held back while the client pipelines frames: with a 1-frame
    // in-flight window the reader must block at least once (a recorded
    // backpressure stall), and every frame still answers correctly once
    // the batch flushes.
    let (svc, bvh, space, cloud) = net_fixture(500, 10_000, Duration::from_millis(60));
    let mut server = NetServer::bind_tcp(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetConfig { max_in_flight: 1, ..Default::default() },
    )
    .expect("bind");
    let addr = server.local_addr().expect("tcp addr");
    let mut client = NetClient::connect_tcp(addr).expect("connect");

    const FRAMES: usize = 8;
    let preds = wire_batch(&cloud.points[..FRAMES * 2], 1.1, 5);
    let expected = expected_rows(&bvh, &space, &preds);
    let ids: Vec<u64> =
        preds.chunks(2).map(|chunk| client.submit(chunk).expect("submit")).collect();
    for (f, id) in ids.iter().enumerate() {
        let response = client.receive().expect("response");
        assert_eq!(response.request_id, *id);
        check_response(
            &format!("frame {f}"),
            &response,
            &preds[f * 2..(f + 1) * 2],
            &expected[f * 2..(f + 1) * 2],
        );
    }
    assert!(
        svc.metrics().net_backpressure_stalls() >= 1,
        "an 8-frame pipeline through a 1-frame window must stall \
         (stalls={})",
        svc.metrics().net_backpressure_stalls()
    );
    server.shutdown();
    svc.shutdown();
}

#[cfg(unix)]
#[test]
fn unix_socket_round_trips_all_kinds() {
    let (svc, bvh, space, cloud) = net_fixture(1000, 32, Duration::from_millis(1));
    let path = std::env::temp_dir().join(format!("arbor_net_{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut server =
        NetServer::bind_unix(Arc::clone(&svc), &path, NetConfig::default()).expect("bind unix");
    assert!(server.local_addr().is_none(), "unix server has no TCP addr");

    let mut client = NetClient::connect_unix(&path).expect("connect unix");
    let preds = wire_batch(&cloud.points[..20], 1.1, 5);
    let expected = expected_rows(&bvh, &space, &preds);
    let response = client.roundtrip(&preds).expect("unix roundtrip");
    check_response("unix", &response, &preds, &expected);

    server.shutdown();
    assert!(!path.exists(), "socket file removed on shutdown");
    svc.shutdown();
}
