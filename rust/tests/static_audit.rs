//! Tier-1 entry point for the repo-wide static audit.
//!
//! `cargo test` runs this along with everything else, so the invariants
//! in [`arbor::audit`] — SAFETY-annotated `unsafe`, NaN-total float
//! ordering, panic-free hot/service modules, exhaustively-threaded wire
//! kinds, and registered bench/example targets — gate the build with
//! zero extra tooling. For human-readable file:line reports (the CI
//! `audit` job), run the standalone reporter:
//! `cargo run --bin arbor-audit`.

use std::path::Path;

#[test]
fn repository_passes_static_audit() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo_root = manifest.parent().expect("rust/ lives under the repo root");
    let diags = arbor::audit::audit_repo(repo_root)
        .expect("audit walk failed (missing layer file or unreadable source)");
    if !diags.is_empty() {
        let report: Vec<String> = diags.iter().map(|d| format!("  {d}")).collect();
        panic!(
            "static audit found {} violation(s):\n{}\n(see src/audit/mod.rs for the rule table and the `audit: allow` escape contract)",
            diags.len(),
            report.join("\n")
        );
    }
}
