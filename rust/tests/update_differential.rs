//! The refit-vs-rebuild differential suite for dynamic scenes.
//!
//! `Bvh::update` keeps the built topology and replaces every box; these
//! tests pin that a refit tree answers *exactly* like a tree freshly
//! rebuilt on the moved boxes — and like the brute-force oracle — for
//! every builder × exec-space × traversal-mode engine, every wire
//! predicate kind, and every motion magnitude from frame-to-frame
//! jitter through teleports that shred the Morton locality. On top of
//! the equivalence grid: wide-layer conservativeness when leaves escape
//! their old parent boxes, the quality metric's refit/rebuild decision,
//! and the service's versioned snapshots under concurrent updates and
//! shutdown races.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use arbor::baselines::brute::BruteForce;
use arbor::bvh::stats::DEFAULT_REBUILD_THRESHOLD;
use arbor::bvh::{Bvh, PredicateKind, QueryOptions, QueryPredicate};
use arbor::coordinator::distributed::{DistributedTree, Partition};
use arbor::coordinator::service::{SearchService, ServiceConfig, SubmitError};
use arbor::data::shapes::Shape;
use arbor::data::workloads::{drift_boxes, jitter_boxes, spatial_radius, teleport_boxes};
use arbor::exec::ExecSpace;
use arbor::geometry::{Aabb, Point};

use common::{
    brute_one, edge_case_boxes, engines, moved_scenes, scene, sorted, wire_batch, PARTITIONS,
    SHAPES,
};

/// True for the kinds whose results are fully ordered on the wire
/// ((distance, index) for the nearest family, (t, index) for first-hit)
/// and must therefore match bit-for-bit, not just as sets.
fn ordered(kind: PredicateKind) -> bool {
    matches!(
        kind,
        PredicateKind::Nearest
            | PredicateKind::NearestSphere
            | PredicateKind::NearestBox
            | PredicateKind::FirstHit
    )
}

/// Asserts one engine's batched output equals the brute oracle on every
/// predicate: bit-identical (distances included) for the ordered kinds,
/// set-identical for the spatial kinds.
fn assert_matches_brute(
    out: &arbor::bvh::QueryOutput,
    preds: &[QueryPredicate],
    brute: &BruteForce,
    ctx: &str,
) {
    for (qi, pred) in preds.iter().enumerate() {
        let (want_idx, want_dist) = brute_one(brute, pred);
        if ordered(pred.kind()) {
            assert_eq!(out.results_for(qi), &want_idx[..], "{ctx}/q{qi}({:?})", pred.kind());
            assert_eq!(out.distances_for(qi), &want_dist[..], "{ctx}/q{qi}({:?})", pred.kind());
        } else {
            assert_eq!(
                sorted(out.results_for(qi).to_vec()),
                sorted(want_idx),
                "{ctx}/q{qi}({:?})",
                pred.kind()
            );
        }
    }
}

#[test]
fn refit_equals_rebuild_equals_brute_for_every_engine_and_motion() {
    // The core equivalence grid: for both workload shapes and all five
    // motion magnitudes, a refit tree (old topology, new boxes) and a
    // freshly rebuilt tree (new topology, new boxes) must return
    // identical results — and both must equal brute force — through all
    // ten wire predicate kinds, for every engine in the grid.
    let radius = spatial_radius(10);
    for shape in SHAPES {
        let (cloud, boxes, _) = scene(shape, 1200, 171);
        for (motion, moved) in moved_scenes(&boxes, cloud.a, 907) {
            let brute = BruteForce::new(&moved);
            // Anchors mix moved-box centroids (hit-rich) with original
            // cloud points (often empty after teleport/collapse).
            let mut anchors: Vec<Point> = moved.iter().step_by(9).map(|b| b.centroid()).collect();
            anchors.extend(cloud.points.iter().step_by(31).copied());
            let preds = wire_batch(&anchors, radius, 10);
            for ((label, fresh, space), (label_r, mut refit, _)) in
                engines(&moved).into_iter().zip(engines(&boxes))
            {
                assert_eq!(label, label_r, "engine grids must align");
                let ctx = format!("{shape:?}/{motion}/{label}");
                refit.update(&space, &moved);
                assert_eq!(refit.validate(), Ok(()), "{ctx}");
                let out_fresh = fresh.query(&space, &preds, &QueryOptions::default());
                let out_refit = refit.query(&space, &preds, &QueryOptions::default());
                for (qi, pred) in preds.iter().enumerate() {
                    if ordered(pred.kind()) {
                        assert_eq!(
                            out_refit.results_for(qi),
                            out_fresh.results_for(qi),
                            "{ctx}/q{qi} refit vs rebuild"
                        );
                        assert_eq!(
                            out_refit.distances_for(qi),
                            out_fresh.distances_for(qi),
                            "{ctx}/q{qi} refit vs rebuild distances"
                        );
                    } else {
                        assert_eq!(
                            sorted(out_refit.results_for(qi).to_vec()),
                            sorted(out_fresh.results_for(qi).to_vec()),
                            "{ctx}/q{qi} refit vs rebuild"
                        );
                    }
                }
                assert_matches_brute(&out_refit, &preds, &brute, &ctx);
            }
        }
    }
}

#[test]
fn repeated_ticks_of_accumulated_motion_stay_exact() {
    // Refits compound: each tick updates the trees already refit on the
    // previous tick, never rebuilding. Every engine must stay valid and
    // brute-exact at every tick.
    let radius = spatial_radius(10);
    let (cloud, boxes, _) = scene(Shape::FilledCube, 800, 61);
    let mut grid = engines(&boxes);
    let mut current = boxes;
    for tick in 0..4u64 {
        current = jitter_boxes(
            &drift_boxes(&current, Point::new(0.4, -0.2, 0.3)),
            0.05 * cloud.a,
            900 + tick,
        );
        let brute = BruteForce::new(&current);
        let anchors: Vec<Point> = current.iter().step_by(11).map(|b| b.centroid()).collect();
        let preds = wire_batch(&anchors, radius, 10);
        for (label, engine, space) in &mut grid {
            engine.update(space, &current);
            assert_eq!(engine.validate(), Ok(()), "tick {tick}/{label}");
            let out = engine.query(space, &preds, &QueryOptions::default());
            assert_matches_brute(&out, &preds, &brute, &format!("tick {tick}/{label}"));
        }
    }
}

#[test]
fn wide_quantization_stays_conservative_when_leaves_escape_their_old_parents() {
    // The quantization regression: teleported leaves land far outside
    // the boxes their frozen ancestors had at build time, so the wide
    // layer's u8 grids must be re-anchored by the update — stale grids
    // would silently clip the escaped leaves out of wide traversal.
    // Every adversarial edge scene is swept, with a span-scaled jump.
    for (name, boxes) in edge_case_boxes() {
        let sb = boxes.iter().fold(Aabb::empty(), |a, b| a.union(b));
        let span = sb.max - sb.min;
        let jump = Point::new(span[0] + 7.0, span[1] + 3.0, span[2] + 11.0);
        let moved = teleport_boxes(&boxes, 5, jump);
        let brute = BruteForce::new(&moved);
        let anchors: Vec<Point> = moved.iter().step_by(4).map(|b| b.centroid()).collect();
        let r = 0.05 * span.norm().max(1.0);
        let preds: Vec<QueryPredicate> = anchors
            .iter()
            .enumerate()
            .map(|(i, p)| {
                if i % 2 == 0 {
                    QueryPredicate::intersects_sphere(*p, r)
                } else {
                    QueryPredicate::nearest(*p, 5)
                }
            })
            .collect();
        for (label, mut engine, space) in engines(&boxes) {
            engine.update(&space, &moved);
            // validate() re-checks per-lane quantized containment of the
            // refit child boxes — the conservativeness proof.
            assert_eq!(engine.validate(), Ok(()), "{name}/{label}");
            let out = engine.query(&space, &preds, &QueryOptions::default());
            assert_matches_brute(&out, &preds, &brute, &format!("{name}/{label}"));
        }
    }
}

#[test]
fn a_single_escaped_leaf_is_found_only_at_its_new_position() {
    // Focused escape scene: one leaf of a regular grid teleports far
    // away. After the update every traversal mode must find it at the
    // new position (exactly it) and no longer at the old one.
    let boxes: Vec<Aabb> = (0..64)
        .map(|i| {
            Aabb::from_point(Point::new(
                (i % 4) as f32,
                ((i / 4) % 4) as f32,
                (i / 16) as f32,
            ))
        })
        .collect();
    let mut moved = boxes.clone();
    let jump = Point::new(100.0, 5.0, -3.0);
    moved[21] = Aabb::new(boxes[21].min + jump, boxes[21].max + jump);
    let old_center = boxes[21].centroid();
    let new_center = moved[21].centroid();
    for (label, mut engine, space) in engines(&boxes) {
        engine.update(&space, &moved);
        assert_eq!(engine.validate(), Ok(()), "{label}");
        let preds = [
            QueryPredicate::intersects_sphere(new_center, 0.4),
            QueryPredicate::intersects_sphere(old_center, 0.4),
            QueryPredicate::nearest(new_center, 1),
        ];
        let out = engine.query(&space, &preds, &QueryOptions::default());
        assert_eq!(out.results_for(0), &[21], "{label}: found at the new position");
        assert!(!out.results_for(1).contains(&21), "{label}: gone from the old position");
        assert_eq!(out.results_for(2), &[21], "{label}: nearest to the new position");
        assert_eq!(out.distances_for(2), &[0.0], "{label}");
    }
}

#[test]
fn quality_metric_separates_teleport_from_small_motion() {
    // The refit-vs-rebuild decision: small jitter and rigid drift keep
    // the frozen topology near its as-built SAH cost, while an
    // index-scattered teleport must push the ratio over the rebuild
    // threshold. Pinned for both builders.
    let space = ExecSpace::with_threads(2);
    let (cloud, boxes, _) = scene(Shape::FilledCube, 2000, 55);
    for builder in [Bvh::build, Bvh::build_apetrei] {
        let mut jittered = builder(&space, &boxes);
        jittered.update(&space, &jitter_boxes(&boxes, 0.02 * cloud.a, 5));
        let q = jittered.refit_quality();
        assert!(q < DEFAULT_REBUILD_THRESHOLD, "small jitter quality {q} must stay refit-able");

        let mut drifted = builder(&space, &boxes);
        drifted.update(&space, &drift_boxes(&boxes, Point::splat(3.5 * cloud.a)));
        let q = drifted.refit_quality();
        assert!((q - 1.0).abs() < 1e-3, "rigid drift is SAH-invariant, got {q}");

        let mut teleported = builder(&space, &boxes);
        teleported.update(&space, &teleport_boxes(&boxes, 7, Point::splat(25.0 * cloud.a)));
        let q = teleported.refit_quality();
        assert!(q > DEFAULT_REBUILD_THRESHOLD, "teleport quality {q} must trigger a rebuild");
    }
}

#[test]
fn service_update_refits_on_jitter_and_rebuilds_on_teleport() {
    // The service-level policy built on the metric: a jitter update
    // publishes the refit, a teleport update publishes a from-scratch
    // rebuild — observable through the report, the epoch counter, and
    // the metrics, and queries answer from the new scene either way.
    let space = ExecSpace::with_threads(2);
    let (cloud, boxes, _) = scene(Shape::FilledCube, 1500, 23);
    let svc =
        SearchService::start(Arc::new(Bvh::build(&space, &boxes)), ServiceConfig::default());
    assert_eq!(svc.epoch(), 0);

    let jittered = jitter_boxes(&boxes, 0.02 * cloud.a, 3);
    let r1 = svc.update(&space, &jittered).expect("update lands");
    assert_eq!(r1.epoch, 1);
    assert_eq!((r1.refit_ranks, r1.rebuilt_ranks), (1, 0), "jitter refits: {r1:?}");
    assert!(r1.quality < DEFAULT_REBUILD_THRESHOLD, "{r1:?}");

    let teleported = teleport_boxes(&boxes, 7, Point::splat(25.0 * cloud.a));
    let r2 = svc.update(&space, &teleported).expect("update lands");
    assert_eq!(r2.epoch, 2);
    assert_eq!((r2.refit_ranks, r2.rebuilt_ranks), (0, 1), "teleport rebuilds: {r2:?}");
    assert!(r2.quality > DEFAULT_REBUILD_THRESHOLD, "{r2:?}");
    assert_eq!(svc.epoch(), 2);
    assert_eq!(svc.metrics().updates(), 2);
    assert_eq!(svc.metrics().update_refit_ranks(), 1);
    assert_eq!(svc.metrics().update_rebuilt_ranks(), 1);

    // Queries now see the teleported scene, exactly — all ten wire
    // kinds, anchored both on moved and on stationary objects.
    let brute = BruteForce::new(&teleported);
    let anchors: Vec<Point> =
        teleported.iter().step_by(75).map(|b| b.centroid()).collect();
    for pred in wire_batch(&anchors, spatial_radius(10), 5) {
        let got = svc.query(pred).expect("running");
        let (want_idx, want_dist) = brute_one(&brute, &pred);
        if ordered(pred.kind()) {
            assert_eq!(got.indices, want_idx, "{:?}", pred.kind());
            assert_eq!(got.distances, want_dist, "{:?}", pred.kind());
        } else {
            assert_eq!(sorted(got.indices), sorted(want_idx), "{:?}", pred.kind());
        }
    }
}

#[test]
fn service_update_length_mismatch_is_malformed_and_publishes_nothing() {
    let space = ExecSpace::serial();
    let (_cloud, boxes, _) = scene(Shape::FilledCube, 100, 77);
    let svc =
        SearchService::start(Arc::new(Bvh::build(&space, &boxes)), ServiceConfig::default());
    assert_eq!(svc.update(&space, &boxes[..99]).err(), Some(SubmitError::Malformed));
    assert_eq!(svc.update(&space, &[]).err(), Some(SubmitError::Malformed));
    assert_eq!(svc.epoch(), 0, "a rejected update publishes nothing");
    assert_eq!(svc.metrics().updates(), 0);
    let ok = svc.update(&space, &drift_boxes(&boxes, Point::splat(2.0))).expect("well-formed");
    assert_eq!(ok.epoch, 1);
}

#[test]
fn concurrent_queries_never_observe_a_torn_scene_version() {
    // Snapshot consistency: all 256 boxes sit on one of two spots, and
    // updates flip the whole scene between them. Any query therefore
    // returns 0 or 256 results — a count in between means the reader
    // saw a half-updated tree, which the Versioned snapshot-per-batch
    // design makes impossible (updates mutate a private clone, never
    // the published tree).
    let n = 256usize;
    let at = |p: Point| -> Vec<Aabb> { (0..n).map(|_| Aabb::from_point(p)).collect() };
    let here = at(Point::origin());
    let there = at(Point::new(1000.0, 0.0, 0.0));
    let space = ExecSpace::serial();
    let svc = Arc::new(SearchService::start(
        Arc::new(Bvh::build(&space, &here)),
        ServiceConfig { max_batch: 16, ..Default::default() },
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..4)
        .map(|_| {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut answered = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let r = svc
                        .query(QueryPredicate::intersects_sphere(Point::origin(), 1.0))
                        .expect("service running");
                    assert!(
                        r.indices.is_empty() || r.indices.len() == n,
                        "torn snapshot: {} of {n} results",
                        r.indices.len()
                    );
                    answered += 1;
                }
                answered
            })
        })
        .collect();
    // Let the readers get queries in flight, then flip the scene under
    // them, pacing the flips so queries interleave with the publishes.
    let t0 = Instant::now();
    while svc.metrics().requests() == 0 && t0.elapsed().as_secs() < 10 {
        std::thread::sleep(Duration::from_millis(1));
    }
    for tick in 0..40 {
        let boxes = if tick % 2 == 0 { &there } else { &here };
        svc.update(&space, boxes).expect("update lands");
        std::thread::sleep(Duration::from_micros(200));
    }
    stop.store(true, Ordering::Relaxed);
    let answered: usize = readers.into_iter().map(|h| h.join().expect("no torn read")).sum();
    assert!(answered > 0, "readers made progress");
    assert_eq!(svc.epoch(), 40);
    assert_eq!(svc.metrics().updates(), 40);
}

#[test]
fn shutdown_racing_update_ends_in_stopped_not_panic() {
    // Regression companion to the submit-side shutdown race: an updater
    // thread hammering `update` while the service shuts down must see
    // each call either land (with the next epoch) or report Stopped —
    // never panic, and never a lost epoch.
    let space = ExecSpace::serial();
    let (_cloud, boxes, _) = scene(Shape::FilledCube, 500, 11);
    let svc = Arc::new(SearchService::start(
        Arc::new(Bvh::build(&space, &boxes)),
        ServiceConfig::default(),
    ));
    let racer = {
        let svc = Arc::clone(&svc);
        let boxes = boxes.clone();
        std::thread::spawn(move || {
            let space = ExecSpace::serial();
            let mut landed = 0u64;
            loop {
                match svc.update(&space, &jitter_boxes(&boxes, 0.1, landed)) {
                    Ok(report) => {
                        assert_eq!(report.epoch, landed + 1, "epochs are gapless");
                        landed += 1;
                    }
                    Err(SubmitError::Stopped) => return landed,
                    Err(e) => panic!("unexpected update error {e:?}"),
                }
            }
        })
    };
    let t0 = Instant::now();
    while svc.metrics().updates() == 0 && t0.elapsed().as_secs() < 10 {
        std::thread::sleep(Duration::from_millis(1));
    }
    svc.shutdown();
    let landed = racer.join().expect("no panic in the race");
    assert!(landed >= 1, "at least one update landed before the stop");
    assert_eq!(svc.epoch(), landed);
    assert_eq!(svc.update(&space, &boxes).err(), Some(SubmitError::Stopped));
}

#[test]
fn distributed_service_update_refits_changed_ranks_and_answers_from_the_new_scene() {
    let space = ExecSpace::with_threads(2);
    let radius = spatial_radius(10);
    for partition in PARTITIONS {
        let (cloud, boxes, _) = scene(Shape::FilledCube, 2000, 313);
        let dt = DistributedTree::build(&space, &boxes, 4, partition);
        let svc = SearchService::start_distributed(Arc::new(dt), ServiceConfig::default());

        // Move only the first quarter of the objects, gently.
        let mut moved = boxes.clone();
        for (i, b) in jitter_boxes(&boxes[..500], 0.02 * cloud.a, 9).into_iter().enumerate() {
            moved[i] = b;
        }
        let r1 = svc.update(&space, &moved).expect("update lands");
        assert_eq!(r1.epoch, 1);
        assert_eq!(r1.refit_ranks + r1.rebuilt_ranks + r1.unchanged_ranks, 4, "{r1:?}");
        assert!(r1.refit_ranks >= 1, "{r1:?}");
        if partition == Partition::Block {
            // Block shards are contiguous index ranges of 500: exactly
            // one rank saw motion, the other three are skipped.
            assert_eq!(r1.unchanged_ranks, 3, "{r1:?}");
            assert_eq!(r1.rebuilt_ranks, 0, "small jitter must not rebuild: {r1:?}");
        }

        // Differential vs brute on the moved scene, every wire kind.
        let brute = BruteForce::new(&moved);
        let anchors: Vec<Point> = cloud.points.iter().step_by(37).copied().collect();
        for pred in wire_batch(&anchors, radius, 10) {
            let got = svc.query(pred).expect("running");
            let (want_idx, want_dist) = brute_one(&brute, &pred);
            if ordered(pred.kind()) {
                assert_eq!(got.indices, want_idx, "{partition:?}/{:?}", pred.kind());
                assert_eq!(got.distances, want_dist, "{partition:?}/{:?}", pred.kind());
            } else {
                assert_eq!(
                    sorted(got.indices),
                    sorted(want_idx),
                    "{partition:?}/{:?}",
                    pred.kind()
                );
            }
        }

        // A scene-wide teleport shreds the per-rank topologies: at least
        // one rank crosses the threshold and is rebuilt.
        let teleported = teleport_boxes(&boxes, 3, Point::splat(40.0 * cloud.a));
        let r2 = svc.update(&space, &teleported).expect("update lands");
        assert_eq!(r2.epoch, 2);
        assert!(r2.rebuilt_ranks >= 1, "teleport must rebuild some rank: {r2:?}");
        assert!(r2.quality > DEFAULT_REBUILD_THRESHOLD, "{r2:?}");
        let probe = teleported[0].centroid();
        let got = svc
            .query(QueryPredicate::intersects_sphere(probe, radius))
            .expect("running")
            .indices;
        let brute2 = BruteForce::new(&teleported);
        let (want, _) =
            brute_one(&brute2, &QueryPredicate::intersects_sphere(probe, radius));
        assert_eq!(sorted(got), sorted(want), "{partition:?} post-teleport");
    }
}
