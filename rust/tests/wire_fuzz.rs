//! Property-style fuzz tests for the byte-level wire codec.
//!
//! The wire is untrusted input, so beyond the deterministic unit tests
//! in `coordinator/wire.rs` this suite drives the codec with randomized
//! inputs: encode→decode round-trip identity over predicates of every
//! kind (via the shared harness's `random_predicate`), and adversarial
//! buffers — truncations, single-bit flips, random garbage, and bad tag
//! bytes — on which `decode`/`decode_batch` must return `None` or a
//! well-formed predicate, never panic, and never report consuming more
//! bytes than exist (no over-read).

mod common;

use arbor::bvh::QueryPredicate;
use arbor::coordinator::wire::{decode, decode_batch, encode, encode_batch, TAG_ATTACH};
use arbor::data::rng::Rng;

use common::random_predicate;

/// Encodes one predicate into a fresh buffer.
fn encoded(pred: &QueryPredicate) -> Vec<u8> {
    let mut bytes = Vec::new();
    encode(pred, &mut bytes);
    bytes
}

#[test]
fn random_predicates_of_every_kind_round_trip() {
    let mut rng = Rng::new(0xF00D);
    let mut kinds_seen = std::collections::HashSet::new();
    for i in 0..2000 {
        let pred = random_predicate(&mut rng, 50.0);
        kinds_seen.insert(pred.kind().name());
        let bytes = encoded(&pred);
        let (decoded, used) = decode(&bytes)
            .unwrap_or_else(|| panic!("round {i}: {pred:?} failed to decode"));
        assert_eq!(used, bytes.len(), "round {i}: {pred:?} under-consumed");
        assert_eq!(decoded, pred, "round {i}");
    }
    // The generator really exercises the whole family (10 kind tags).
    assert_eq!(kinds_seen.len(), arbor::bvh::PredicateKind::COUNT, "{kinds_seen:?}");
}

#[test]
fn random_batches_round_trip_back_to_back() {
    let mut rng = Rng::new(0xBA7C);
    for _ in 0..50 {
        let preds: Vec<QueryPredicate> =
            (0..1 + rng.below(40)).map(|_| random_predicate(&mut rng, 20.0)).collect();
        let mut bytes = Vec::new();
        encode_batch(&preds, &mut bytes);
        assert_eq!(decode_batch(&bytes).expect("batch decodes"), preds);
    }
}

#[test]
fn truncations_never_panic_or_over_read() {
    let mut rng = Rng::new(0x7A11);
    for _ in 0..200 {
        let pred = random_predicate(&mut rng, 30.0);
        let bytes = encoded(&pred);
        // Every strict prefix of a single predicate is malformed.
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut]).is_none(), "{pred:?} truncated at {cut}");
        }
        // A batch with a truncated tail poisons the whole batch.
        let mut batch = bytes.clone();
        batch.extend_from_slice(&bytes[..bytes.len() - 1]);
        assert!(decode_batch(&batch).is_none(), "{pred:?} truncated batch tail");
    }
}

#[test]
fn bit_flips_never_panic_or_over_read() {
    let mut rng = Rng::new(0xB17F);
    for _ in 0..300 {
        let pred = random_predicate(&mut rng, 30.0);
        let mut bytes = encoded(&pred);
        let byte = rng.below(bytes.len());
        let bit = rng.below(8);
        bytes[byte] ^= 1 << bit;
        // A flipped buffer may decode to a *different valid* predicate
        // (flipping a payload bit changes a coordinate) or be rejected —
        // but it must never panic and never claim bytes it does not have.
        match decode(&bytes) {
            Some((decoded, used)) => {
                assert!(used <= bytes.len(), "{pred:?} over-read after bit flip");
                // Whatever decoded must re-encode to something decodable
                // (decoded predicates are always well-formed).
                let re = encoded(&decoded);
                assert!(decode(&re).is_some(), "{decoded:?} must stay decodable");
            }
            None => {}
        }
        // decode_batch on the same buffer obeys the same contract.
        let _ = decode_batch(&bytes);
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::new(0x6A5B);
    for _ in 0..500 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        if let Some((decoded, used)) = decode(&bytes) {
            assert!(used <= bytes.len(), "over-read on garbage");
            assert!(decode(&encoded(&decoded)).is_some());
        }
        let _ = decode_batch(&bytes);
    }
}

#[test]
fn bad_tags_are_rejected_with_any_payload() {
    // Valid plain tags are 1..=7; valid attach tags are 0x81..=0x83.
    // Everything else must be rejected no matter how much payload
    // follows.
    let payload = [0u8; 64];
    let valid_plain: std::ops::RangeInclusive<u8> = 1..=7;
    let valid_attach = [0x81u8, 0x82, 0x83];
    for tag in 0u8..=255 {
        if valid_plain.contains(&tag) || valid_attach.contains(&tag) {
            continue;
        }
        let mut bytes = vec![tag];
        bytes.extend_from_slice(&payload);
        assert!(decode(&bytes).is_none(), "tag {tag:#04x} must be rejected");
    }
    // Attach-flagged nearest/first-hit tags specifically (the guard in
    // the decoder's match arms).
    for tag in [4u8, 5, 6, 7] {
        let mut bytes = vec![tag | TAG_ATTACH];
        bytes.extend_from_slice(&payload);
        assert!(decode(&bytes).is_none(), "attached tag {tag} must be rejected");
    }
}
