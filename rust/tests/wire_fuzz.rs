//! Property-style fuzz tests for the byte-level wire codec.
//!
//! The wire is untrusted input, so beyond the deterministic unit tests
//! in `coordinator/wire.rs` this suite drives the codec with randomized
//! inputs: encode→decode round-trip identity over predicates of every
//! kind (via the shared harness's `random_predicate`), and adversarial
//! buffers — truncations, single-bit flips, random garbage, and bad tag
//! bytes — on which `decode`/`decode_batch` must return `None` or a
//! well-formed predicate, never panic, and never report consuming more
//! bytes than exist (no over-read).
//!
//! The framing layer runs the same gauntlet: random length prefixes
//! (including multi-gigabyte declarations), truncation at every cut
//! point, bit flips, and garbage must never panic, never claim bytes
//! beyond the buffer, and never demand an allocation — `parse_frame`
//! is non-allocating by construction and the declared length is gated
//! against `MAX_FRAME_LEN` before the caller buffers anything. The
//! response records (`decode_result` / `decode_response_body`) gate
//! their declared counts against the bytes present the same way.

mod common;

use arbor::bvh::QueryPredicate;
use arbor::coordinator::wire::{
    batch_tags, decode, decode_batch, decode_response_body, decode_result, encode, encode_batch,
    encode_frame, encode_result, parse_frame, parse_frame_with, wire_tag, FrameParse,
    MAX_FRAME_LEN, MAX_RESPONSE_LEN, STATUS_OK, TAG_ATTACH,
};
use arbor::data::rng::Rng;

use common::random_predicate;

/// Encodes one predicate into a fresh buffer.
fn encoded(pred: &QueryPredicate) -> Vec<u8> {
    let mut bytes = Vec::new();
    encode(pred, &mut bytes);
    bytes
}

#[test]
fn random_predicates_of_every_kind_round_trip() {
    let mut rng = Rng::new(0xF00D);
    let mut kinds_seen = std::collections::HashSet::new();
    for i in 0..2000 {
        let pred = random_predicate(&mut rng, 50.0);
        kinds_seen.insert(pred.kind().name());
        let bytes = encoded(&pred);
        let (decoded, used) = decode(&bytes)
            .unwrap_or_else(|| panic!("round {i}: {pred:?} failed to decode"));
        assert_eq!(used, bytes.len(), "round {i}: {pred:?} under-consumed");
        assert_eq!(decoded, pred, "round {i}");
    }
    // The generator really exercises the whole family (10 kind tags).
    assert_eq!(kinds_seen.len(), arbor::bvh::PredicateKind::COUNT, "{kinds_seen:?}");
}

#[test]
fn random_batches_round_trip_back_to_back() {
    let mut rng = Rng::new(0xBA7C);
    for _ in 0..50 {
        let preds: Vec<QueryPredicate> =
            (0..1 + rng.below(40)).map(|_| random_predicate(&mut rng, 20.0)).collect();
        let mut bytes = Vec::new();
        encode_batch(&preds, &mut bytes);
        assert_eq!(decode_batch(&bytes).expect("batch decodes"), preds);
    }
}

#[test]
fn truncations_never_panic_or_over_read() {
    let mut rng = Rng::new(0x7A11);
    for _ in 0..200 {
        let pred = random_predicate(&mut rng, 30.0);
        let bytes = encoded(&pred);
        // Every strict prefix of a single predicate is malformed.
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut]).is_none(), "{pred:?} truncated at {cut}");
        }
        // A batch with a truncated tail poisons the whole batch.
        let mut batch = bytes.clone();
        batch.extend_from_slice(&bytes[..bytes.len() - 1]);
        assert!(decode_batch(&batch).is_none(), "{pred:?} truncated batch tail");
    }
}

#[test]
fn bit_flips_never_panic_or_over_read() {
    let mut rng = Rng::new(0xB17F);
    for _ in 0..300 {
        let pred = random_predicate(&mut rng, 30.0);
        let mut bytes = encoded(&pred);
        let byte = rng.below(bytes.len());
        let bit = rng.below(8);
        bytes[byte] ^= 1 << bit;
        // A flipped buffer may decode to a *different valid* predicate
        // (flipping a payload bit changes a coordinate) or be rejected —
        // but it must never panic and never claim bytes it does not have.
        match decode(&bytes) {
            Some((decoded, used)) => {
                assert!(used <= bytes.len(), "{pred:?} over-read after bit flip");
                // Whatever decoded must re-encode to something decodable
                // (decoded predicates are always well-formed).
                let re = encoded(&decoded);
                assert!(decode(&re).is_some(), "{decoded:?} must stay decodable");
            }
            None => {}
        }
        // decode_batch on the same buffer obeys the same contract.
        let _ = decode_batch(&bytes);
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::new(0x6A5B);
    for _ in 0..500 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        if let Some((decoded, used)) = decode(&bytes) {
            assert!(used <= bytes.len(), "over-read on garbage");
            assert!(decode(&encoded(&decoded)).is_some());
        }
        let _ = decode_batch(&bytes);
    }
}

#[test]
fn bad_tags_are_rejected_with_any_payload() {
    // Valid plain tags are 1..=7; valid attach tags are 0x81..=0x83.
    // Everything else must be rejected no matter how much payload
    // follows.
    let payload = [0u8; 64];
    let valid_plain: std::ops::RangeInclusive<u8> = 1..=7;
    let valid_attach = [0x81u8, 0x82, 0x83];
    for tag in 0u8..=255 {
        if valid_plain.contains(&tag) || valid_attach.contains(&tag) {
            continue;
        }
        let mut bytes = vec![tag];
        bytes.extend_from_slice(&payload);
        assert!(decode(&bytes).is_none(), "tag {tag:#04x} must be rejected");
    }
    // Attach-flagged nearest/first-hit tags specifically (the guard in
    // the decoder's match arms).
    for tag in [4u8, 5, 6, 7] {
        let mut bytes = vec![tag | TAG_ATTACH];
        bytes.extend_from_slice(&payload);
        assert!(decode(&bytes).is_none(), "attached tag {tag} must be rejected");
    }
}

/// Encodes a random batch into a random-id frame; returns (id, body,
/// frame).
fn random_frame(rng: &mut Rng) -> (u64, Vec<u8>, Vec<u8>) {
    let preds: Vec<QueryPredicate> =
        (0..1 + rng.below(12)).map(|_| random_predicate(rng, 25.0)).collect();
    let mut body = Vec::new();
    encode_batch(&preds, &mut body);
    let request_id = rng.next_u64();
    let mut frame = Vec::new();
    encode_frame(request_id, &body, &mut frame);
    (request_id, body, frame)
}

#[test]
fn framed_random_batches_round_trip_pipelined() {
    let mut rng = Rng::new(0xF4A3);
    for _ in 0..40 {
        // A pipeline of several frames back to back parses in order,
        // each body bit-identical and batch_tags agreeing with decode.
        let frames: Vec<(u64, Vec<u8>, Vec<u8>)> =
            (0..1 + rng.below(5)).map(|_| random_frame(&mut rng)).collect();
        let pipe: Vec<u8> = frames.iter().flat_map(|(_, _, f)| f.iter().copied()).collect();
        let mut offset = 0;
        for (request_id, body, _) in &frames {
            match parse_frame(&pipe[offset..]) {
                FrameParse::Frame { request_id: id, body_start, body_end, used } => {
                    assert_eq!(id, *request_id);
                    let got = &pipe[offset + body_start..offset + body_end];
                    assert_eq!(got, &body[..], "body survives framing");
                    let preds = decode_batch(got).expect("body decodes");
                    let tags = batch_tags(got).expect("size-table walk");
                    assert_eq!(tags.len(), preds.len());
                    for (tag, pred) in tags.iter().zip(&preds) {
                        assert_eq!(*tag, wire_tag(pred));
                    }
                    offset += used;
                }
                other => panic!("pipelined frame: {other:?}"),
            }
        }
        assert_eq!(offset, pipe.len(), "pipeline fully consumed");
    }
}

#[test]
fn frame_truncation_at_every_cut_point_is_incomplete() {
    let mut rng = Rng::new(0x7C07);
    for _ in 0..30 {
        let (_, _, frame) = random_frame(&mut rng);
        for cut in 0..frame.len() {
            // A prefix of a valid frame is always Incomplete — never
            // Malformed (the connection would die) and never a Frame
            // (that would over-read).
            assert_eq!(
                parse_frame(&frame[..cut]),
                FrameParse::Incomplete,
                "cut {cut} of {}",
                frame.len()
            );
        }
    }
}

#[test]
fn random_length_prefixes_are_gated_not_trusted() {
    // The 4-byte header is hostile: whatever it declares, the parser
    // must verdict from the gate alone — `Malformed` outside
    // (8, 8 + MAX_FRAME_LEN], `Incomplete` inside (the body bytes are
    // not there) — and must do so without allocating or reading beyond
    // the 12 buffered bytes.
    let mut rng = Rng::new(0x1E46);
    for _ in 0..2000 {
        let declared = rng.next_u64() as u32;
        let mut bytes = declared.to_le_bytes().to_vec();
        let id = rng.next_u64();
        bytes.extend_from_slice(&id.to_le_bytes());
        let len = declared as usize;
        let expect = if len <= 8 || len > 8 + MAX_FRAME_LEN {
            FrameParse::Malformed { request_id: Some(id) }
        } else {
            FrameParse::Incomplete
        };
        assert_eq!(parse_frame(&bytes), expect, "declared {declared}");
        // With only the 4 header bytes the verdict can at most lose the
        // id — it must never upgrade to Frame.
        match parse_frame(&bytes[..4]) {
            FrameParse::Frame { .. } => panic!("Frame from a bare header"),
            FrameParse::Incomplete | FrameParse::Malformed { .. } => {}
        }
    }
    // Multi-gigabyte declarations specifically.
    for declared in [u32::MAX, u32::MAX - 1, (1 << 31) as u32, (8 + MAX_FRAME_LEN + 1) as u32] {
        let mut bytes = declared.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 8]);
        assert!(
            matches!(parse_frame(&bytes), FrameParse::Malformed { .. }),
            "{declared} must be rejected before any buffering"
        );
    }
}

#[test]
fn frame_bit_flips_and_garbage_never_panic_or_over_read() {
    let mut rng = Rng::new(0xFB17);
    for _ in 0..300 {
        let (_, _, mut frame) = random_frame(&mut rng);
        let byte = rng.below(frame.len());
        frame[byte] ^= 1 << rng.below(8);
        match parse_frame(&frame) {
            FrameParse::Frame { body_start, body_end, used, .. } => {
                assert!(used <= frame.len(), "over-read after bit flip");
                assert!(body_start <= body_end && body_end <= used);
                // The body may no longer decode — but it must not panic.
                let _ = decode_batch(&frame[body_start..body_end]);
                let _ = batch_tags(&frame[body_start..body_end]);
            }
            FrameParse::Incomplete | FrameParse::Malformed { .. } => {}
        }
    }
    for _ in 0..500 {
        let len = rng.below(96);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        for parsed in [parse_frame(&bytes), parse_frame_with(&bytes, MAX_RESPONSE_LEN)] {
            if let FrameParse::Frame { body_start, body_end, used, .. } = parsed {
                assert!(used <= bytes.len(), "over-read on garbage");
                assert!(body_start <= body_end && body_end <= used);
            }
        }
    }
}

#[test]
fn response_records_round_trip_and_garbage_is_gated() {
    let mut rng = Rng::new(0x4E52);
    for _ in 0..200 {
        // Random well-formed response: random tags with plausible rows.
        let n = 1 + rng.below(10);
        let mut body = vec![STATUS_OK];
        body.extend_from_slice(&(n as u32).to_le_bytes());
        let mut expected = Vec::new();
        for _ in 0..n {
            let pred = random_predicate(&mut rng, 25.0);
            let tag = wire_tag(&pred);
            let indices: Vec<u32> = (0..rng.below(6)).map(|_| rng.next_u64() as u32).collect();
            let distances: Vec<f32> =
                (0..rng.below(6)).map(|_| rng.uniform(0.0, 100.0) as f32).collect();
            let data = (tag & TAG_ATTACH != 0).then(|| rng.next_u64());
            encode_result(tag, &indices, &distances, data, &mut body);
            expected.push((tag, indices, distances, data));
        }
        let (status, results) = decode_response_body(&body).expect("round trip");
        assert_eq!(status, STATUS_OK);
        assert_eq!(results.len(), expected.len());
        for (r, (tag, indices, distances, data)) in results.iter().zip(&expected) {
            assert_eq!(r.tag, *tag);
            assert_eq!(&r.indices, indices);
            assert_eq!(&r.distances, distances);
            assert_eq!(r.data, *data);
        }
        // Truncation anywhere kills the body cleanly.
        for cut in 0..body.len() {
            assert!(decode_response_body(&body[..cut]).is_none(), "cut {cut}");
        }
    }
    // Hostile counts: a short buffer declaring u32::MAX rows must be
    // rejected by arithmetic before anything is reserved.
    for _ in 0..500 {
        let len = rng.below(64);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        if let Some((_, used)) = decode_result(&bytes) {
            assert!(used <= bytes.len(), "over-read on garbage record");
        }
        let _ = decode_response_body(&bytes);
    }
}
